//! What-if speedup bounds: replay the attributed critical path under
//! counterfactuals and price each ROADMAP optimization before anyone
//! builds it.
//!
//! Each counterfactual removes a measured cycle category from every
//! device's busy extent and recomputes the pool makespan — so the
//! result is a *bound* ("double-buffered installs are worth at most
//! 1.15x here"), never a promise. All estimates live purely in the
//! deterministic simulated-cycle domain of [`super::critpath`]: the
//! same trace always prices the same, and every predicted makespan is
//! provably ≤ the measured one (removal only shrinks extents), which
//! the randomized proptest pins over arbitrary wave mixes.
//!
//! The four counterfactuals and the ROADMAP items they price:
//!
//! * `installs_hidden` — every install overlaps with that device's own
//!   compute (capped at the compute+overhead available to hide behind):
//!   the **double-buffered weight install** item.
//! * `perfect_weight_cache` — no install ever happens (infinite
//!   resident capacity): the upper bound on any caching/prefetch work.
//! * `zero_queue_wait` — inter-job queue-wait gaps vanish: the **async
//!   serving front end** item. Device tracks are saturated today
//!   (jobs run back-to-back in simulated cycles), so this measures 0
//!   until the scheduler itself leaves cycle gaps — wall-clock wait
//!   lives in the queue-wait histograms, not in this bound, and the
//!   report says so rather than inventing a number.
//! * `perfect_balance` — work spreads evenly across devices
//!   (`ceil(total busy / devices)`): prices the scheduler-gap slice
//!   that placement and stealing leave behind.

use std::fmt::Write as _;

use super::critpath::Attribution;
use crate::bench_harness::report::fnum;
use crate::jsonio::Json;

/// One priced counterfactual.
#[derive(Debug, Clone)]
pub struct Counterfactual {
    pub name: &'static str,
    /// The ROADMAP item (or structural question) this bound prices.
    pub prices: &'static str,
    /// Total cycles removed across all device tracks.
    pub removed_cycles: u64,
    /// Pool makespan after removal — always ≤ the measured makespan.
    pub predicted_makespan: u64,
    /// `measured / predicted` — the speedup upper bound.
    pub speedup_bound: f64,
}

/// The what-if report for one attributed trace.
#[derive(Debug, Clone)]
pub struct WhatIfReport {
    pub measured_makespan: u64,
    /// `install / (install + compute + overhead)` from the attribution
    /// (equal to `weight_load_cycles_charged / sim_cycles` on a
    /// conserving trace).
    pub install_share: f64,
    pub counterfactuals: Vec<Counterfactual>,
}

fn speedup(measured: u64, predicted: u64) -> f64 {
    if measured == 0 {
        1.0
    } else {
        measured as f64 / predicted.max(1) as f64
    }
}

/// Price every counterfactual against an attribution.
pub fn what_if(attr: &Attribution) -> WhatIfReport {
    let measured = attr.makespan;
    // Remove `removed(d)` cycles from each device's busy extent; the
    // new makespan is the slowest surviving track.
    let replay = |name: &'static str,
                  prices: &'static str,
                  removed: &dyn Fn(&super::critpath::DeviceAttribution) -> u64|
     -> Counterfactual {
        let mut total_removed = 0u64;
        let mut predicted = 0u64;
        for d in &attr.devices {
            let r = removed(d).min(d.busy_end);
            total_removed += r;
            predicted = predicted.max(d.busy_end - r);
        }
        Counterfactual {
            name,
            prices,
            removed_cycles: total_removed,
            predicted_makespan: predicted,
            speedup_bound: speedup(measured, predicted),
        }
    };

    let mut counterfactuals = vec![
        replay(
            "installs_hidden",
            "double-buffered weight install (ROADMAP): install overlaps compute",
            &|d| d.cats.install_cycles.min(d.cats.compute_cycles + d.cats.overhead_cycles),
        ),
        replay(
            "perfect_weight_cache",
            "infinite resident weight capacity: no install ever happens",
            &|d| d.cats.install_cycles,
        ),
        replay(
            "zero_queue_wait",
            "async serving front end (ROADMAP): inter-job cycle gaps vanish",
            &|d| d.cats.queue_wait_cycles,
        ),
    ];

    // Perfect balance: spread the total busy work evenly. The measured
    // makespan is the max per-device extent, which is ≥ the mean, so
    // the bound property holds here too.
    let total_busy: u64 = attr.devices.iter().map(|d| d.busy_end).sum();
    let n = attr.devices.len() as u64;
    let balanced = if n == 0 { 0 } else { total_busy.div_ceil(n) };
    counterfactuals.push(Counterfactual {
        name: "perfect_balance",
        prices: "ideal placement/stealing: every device finishes together",
        removed_cycles: attr.totals.gap_cycles,
        predicted_makespan: balanced,
        speedup_bound: speedup(measured, balanced),
    });

    debug_assert!(
        counterfactuals.iter().all(|c| c.predicted_makespan <= measured),
        "a counterfactual may only remove cycles"
    );
    WhatIfReport { measured_makespan: measured, install_share: attr.install_share(), counterfactuals }
}

impl WhatIfReport {
    /// Look one bound up by name (tests and the dashboard use this).
    pub fn bound(&self, name: &str) -> Option<&Counterfactual> {
        self.counterfactuals.iter().find(|c| c.name == name)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "what-if bounds (measured makespan {} cycles, install share {}%):",
            self.measured_makespan,
            fnum(self.install_share * 100.0, 1)
        );
        for c in &self.counterfactuals {
            let _ = writeln!(
                out,
                "  {:<22} -{:<8} cycles  predicted {:<8}  speedup <= {}x",
                c.name,
                c.removed_cycles,
                c.predicted_makespan,
                fnum(c.speedup_bound, 3)
            );
            let _ = writeln!(out, "  {:<22} prices: {}", "", c.prices);
        }
        if self.bound("zero_queue_wait").is_some_and(|c| c.removed_cycles == 0) {
            let _ = writeln!(
                out,
                "  note: device tracks are saturated in simulated cycles; queue wait shows up \
                 in the wall-clock wait histograms, not in this cycle bound"
            );
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("measured_makespan_cycles", Json::num(self.measured_makespan as f64)),
            ("install_share", Json::num(self.install_share)),
            (
                "counterfactuals",
                Json::Arr(
                    self.counterfactuals
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("name", Json::str(c.name)),
                                ("prices", Json::str(c.prices)),
                                ("removed_cycles", Json::num(c.removed_cycles as f64)),
                                (
                                    "predicted_makespan_cycles",
                                    Json::num(c.predicted_makespan as f64),
                                ),
                                ("speedup_bound", Json::num(c.speedup_bound)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::critpath::{Attribution, Categories, DeviceAttribution};

    /// The golden 2-device scenario's attribution, constructed
    /// literally (critpath's golden test pins that `attribute` produces
    /// exactly these numbers from the real device runs).
    fn golden_attr() -> Attribution {
        let d0 = DeviceAttribution {
            device: 0,
            jobs: 2,
            busy_end: 35,
            cats: Categories {
                install_cycles: 7,
                compute_cycles: 12,
                overhead_cycles: 16,
                gap_cycles: 20,
                ..Categories::default()
            },
            critical: false,
        };
        let d1 = DeviceAttribution {
            device: 1,
            jobs: 3,
            busy_end: 55,
            cats: Categories {
                install_cycles: 7,
                compute_cycles: 24,
                overhead_cycles: 24,
                ..Categories::default()
            },
            critical: true,
        };
        let mut totals = Categories::default();
        for d in [&d0, &d1] {
            totals.install_cycles += d.cats.install_cycles;
            totals.compute_cycles += d.cats.compute_cycles;
            totals.overhead_cycles += d.cats.overhead_cycles;
            totals.gap_cycles += d.cats.gap_cycles;
        }
        Attribution { makespan: 55, budget: 110, devices: vec![d0, d1], totals, waves: Vec::new() }
    }

    #[test]
    fn golden_bounds_are_pinned() {
        let r = what_if(&golden_attr());
        assert_eq!(r.measured_makespan, 55);
        assert!((r.install_share - 14.0 / 90.0).abs() < 1e-12);

        // Both installs fully hide behind their own device's compute:
        // the critical device drops to 55 - 7 = 48.
        let hidden = r.bound("installs_hidden").unwrap();
        assert_eq!(hidden.removed_cycles, 14);
        assert_eq!(hidden.predicted_makespan, 48);
        assert!((hidden.speedup_bound - 55.0 / 48.0).abs() < 1e-12);

        // A perfect cache removes the same installs here (each device's
        // install fits under its hideable compute already).
        let cache = r.bound("perfect_weight_cache").unwrap();
        assert_eq!(cache.predicted_makespan, 48);

        // Saturated tracks: zero queue wait changes nothing, honestly.
        let wait = r.bound("zero_queue_wait").unwrap();
        assert_eq!(wait.removed_cycles, 0);
        assert_eq!(wait.predicted_makespan, 55);
        assert!((wait.speedup_bound - 1.0).abs() < 1e-12);

        // Perfect balance: ceil((35 + 55) / 2) = 45.
        let bal = r.bound("perfect_balance").unwrap();
        assert_eq!(bal.predicted_makespan, 45);
        assert!((bal.speedup_bound - 55.0 / 45.0).abs() < 1e-12);
    }

    #[test]
    fn bounds_never_exceed_measured_latency() {
        let r = what_if(&golden_attr());
        for c in &r.counterfactuals {
            assert!(c.predicted_makespan <= r.measured_makespan, "{}", c.name);
            assert!(c.speedup_bound >= 1.0, "{}", c.name);
        }
    }

    #[test]
    fn install_bigger_than_hideable_compute_is_capped() {
        // One device, install 30 but only 10 cycles of compute to hide
        // behind: installs_hidden may remove at most 10, while the
        // perfect cache removes all 30.
        let d = DeviceAttribution {
            device: 0,
            jobs: 1,
            busy_end: 40,
            cats: Categories {
                install_cycles: 30,
                compute_cycles: 6,
                overhead_cycles: 4,
                ..Categories::default()
            },
            critical: true,
        };
        let attr = Attribution {
            makespan: 40,
            budget: 40,
            totals: d.cats,
            devices: vec![d],
            waves: Vec::new(),
        };
        let r = what_if(&attr);
        assert_eq!(r.bound("installs_hidden").unwrap().predicted_makespan, 30);
        assert_eq!(r.bound("perfect_weight_cache").unwrap().predicted_makespan, 10);
    }

    #[test]
    fn empty_attribution_prices_nothing() {
        let attr = Attribution {
            makespan: 0,
            budget: 0,
            devices: Vec::new(),
            totals: Categories::default(),
            waves: Vec::new(),
        };
        let r = what_if(&attr);
        for c in &r.counterfactuals {
            assert_eq!(c.predicted_makespan, 0, "{}", c.name);
            assert!((c.speedup_bound - 1.0).abs() < 1e-12, "{}", c.name);
        }
    }

    #[test]
    fn whatif_json_round_trips() {
        let r = what_if(&golden_attr());
        let back = Json::parse(&r.to_json().render()).unwrap();
        assert_eq!(back.get("measured_makespan_cycles").unwrap().as_u64(), Some(55));
        let cfs = back.get("counterfactuals").unwrap().as_arr().unwrap();
        assert_eq!(cfs.len(), 4);
        assert_eq!(cfs[0].get("name").unwrap().as_str(), Some("installs_hidden"));
        assert_eq!(cfs[0].get("predicted_makespan_cycles").unwrap().as_u64(), Some(48));
    }

    #[test]
    fn render_names_every_counterfactual() {
        let r = what_if(&golden_attr());
        let text = r.render();
        for c in &r.counterfactuals {
            assert!(text.contains(c.name), "render must show {}", c.name);
        }
        assert!(text.contains("saturated"), "the zero-wait caveat must be stated");
    }
}
