//! Clock discipline: the blessed wall-clock entry point.
//!
//! The repo's primary clock is *simulated cycles* — deterministic,
//! diffable, owned by the device model. Wall time is secondary and
//! easy to abuse: scattering `Instant::now()` through serving code
//! makes traces non-reproducible and invites accidental timestamping
//! inside hot regions. So serving/arch code takes wall time only
//! through [`start`], and `dip lint` bans raw `Instant::now()` /
//! `SystemTime::now()` on those paths (the `no-raw-wall-clock` rule)
//! the same way it bans unannotated truncating casts. Coordinator
//! internals (queue-wait stamping, busy accounting) and this module
//! are the allowlisted clock owners.

use std::time::{Duration, Instant};

/// A started wall-clock measurement. Thin wrapper over [`Instant`] so
/// call sites read as *measurement*, not *timestamping*.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Instant);

/// Start a wall-clock measurement (the one sanctioned way for
/// serving/arch code to touch wall time).
pub fn start() -> Stopwatch {
    Stopwatch(Instant::now())
}

impl Stopwatch {
    /// Wall time elapsed since [`start`].
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    /// Elapsed wall nanoseconds, saturated into `u64` (585 years).
    pub fn elapsed_ns(&self) -> u64 {
        u64::try_from(self.0.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_nonnegative_monotone_time() {
        let sw = start();
        let a = sw.elapsed_ns();
        let b = sw.elapsed_ns();
        assert!(b >= a);
        assert!(sw.elapsed().as_nanos() >= b as u128);
    }
}
