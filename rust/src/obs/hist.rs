//! Allocation-free log2-bucketed latency histograms.
//!
//! A [`Hist`] is a fixed `[u64; 64]` bucket array plus count/sum/max —
//! `Copy`, mergeable, and recordable with a handful of integer ops
//! (`leading_zeros` + three adds), so it can live inside per-device
//! observers and per-tenant counters without ever allocating or
//! locking on the record path. Bucket `b >= 1` holds values in
//! `[2^(b-1), 2^b - 1]`; bucket 0 holds exactly 0. Quantiles come back
//! as the upper bound of the bucket containing the requested rank,
//! clamped to the observed maximum — a <= 2x relative overestimate by
//! construction, which is the usual log2-histogram contract (and why
//! p50/p95/p99 here are summaries, not exact order statistics).

/// Number of log2 buckets — one per possible `u64` magnitude.
pub const HIST_BUCKETS: usize = 64;

/// Mergeable log2-bucketed histogram of `u64` samples (latencies in
/// ns or simulated cycles). `Copy` on purpose: snapshots embed it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hist {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Self { buckets: [0; HIST_BUCKETS], count: 0, sum: 0, max: 0 }
    }
}

impl Hist {
    /// Bucket index of a sample: 0 for 0, else `1 + floor(log2 v)`,
    /// saturated into the last bucket.
    fn bucket(v: u64) -> usize {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper bound of a bucket (what quantiles report).
    fn bucket_hi(b: usize) -> u64 {
        if b == 0 {
            0
        } else if b >= HIST_BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << b) - 1
        }
    }

    /// Record one sample. No allocation, no branching beyond the
    /// saturating sum.
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram in (device → pool, tenant → global).
    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket holding the sample at quantile
    /// `q ∈ [0, 1]`, clamped to the observed max. 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Self::bucket_hi(b).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// `p50/p95/p99 (n=count)` — the dashboard summary cell.
    pub fn summary(&self) -> String {
        format!("{}/{}/{} (n={})", self.p50(), self.p95(), self.p99(), self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_exact_powers_of_two() {
        assert_eq!(Hist::bucket(0), 0);
        assert_eq!(Hist::bucket(1), 1);
        assert_eq!(Hist::bucket(2), 2);
        assert_eq!(Hist::bucket(3), 2);
        assert_eq!(Hist::bucket(4), 3);
        assert_eq!(Hist::bucket(1023), 10);
        assert_eq!(Hist::bucket(1024), 11);
        assert_eq!(Hist::bucket(u64::MAX), 63);
        assert_eq!(Hist::bucket_hi(0), 0);
        assert_eq!(Hist::bucket_hi(1), 1);
        assert_eq!(Hist::bucket_hi(10), 1023);
        assert_eq!(Hist::bucket_hi(63), u64::MAX);
    }

    #[test]
    fn count_sum_max_mean_track_samples() {
        let mut h = Hist::default();
        for v in [0u64, 1, 5, 100, 7] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 113);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 22.6).abs() < 1e-9);
    }

    #[test]
    fn quantiles_report_bucket_upper_bounds_clamped_to_max() {
        let mut h = Hist::default();
        // 100 samples of 10 (bucket 4, hi 15) + 1 sample of 1000
        // (bucket 10, hi 1023).
        for _ in 0..100 {
            h.record(10);
        }
        h.record(1000);
        assert_eq!(h.p50(), 15);
        assert_eq!(h.p95(), 15);
        assert_eq!(h.p99(), 15);
        assert_eq!(h.quantile(1.0), 1000); // bucket hi 1023 clamps to max
        // All-equal samples: every quantile is the bucket bound clamped
        // to the one observed value.
        let mut one = Hist::default();
        one.record(7);
        assert_eq!(one.p50(), 7);
        assert_eq!(one.p99(), 7);
    }

    #[test]
    fn empty_hist_is_all_zeros() {
        let h = Hist::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p99(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn merge_is_bucketwise_sum() {
        let mut a = Hist::default();
        let mut b = Hist::default();
        let mut whole = Hist::default();
        for v in [1u64, 2, 3, 900] {
            a.record(v);
            whole.record(v);
        }
        for v in [4u64, 0, 65_000] {
            b.record(v);
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a, whole);
    }

    #[test]
    fn zero_samples_land_in_bucket_zero() {
        let mut h = Hist::default();
        h.record(0);
        h.record(0);
        h.record(8);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.quantile(1.0), 8);
    }
}
