//! Flight-recorder observability: always-on event tracing, latency
//! histograms, and analytical-model drift telemetry.
//!
//! The repo's counters ([`MetricsSnapshot`]) say *how much* happened;
//! this module says *when* and *why* — cheap enough to leave on.
//!
//! # Event model
//!
//! Typed [`Event`]s cover the full job lifecycle — `submit → enqueue →
//! pop/steal → install-or-skip → kernel → complete` — plus wave
//! open/close, session join/leave, prepared-cache hit/miss, and
//! backpressure, each stamped with the causal ids that apply (request,
//! session, wave, tenant, tile, device). Three event kinds are *spans*
//! (job, install, kernel — install and kernel nest inside their job);
//! the rest are instants. Events land in fixed-slot rings
//! ([`EventRing`]): one lock-free ring *owned by each device* (moved
//! into its worker thread; published once at exit) and one shared
//! control ring behind a leaf mutex for the coarse submission/wave
//! paths.
//!
//! # Clock domains
//!
//! The **primary clock is simulated cycles**: each device track is
//! stamped with that device's cumulative executed cycles, so two runs
//! of the same deterministic scenario produce byte-identical span
//! timelines — traces are diffable artifacts, not flaky timings. The
//! control track is clocked by a monotone sequence number. Wall-clock
//! nanoseconds ride along as a secondary field (`wall_ns`) and in the
//! step/wave latency histograms, but never drive `ts` ordering.
//! Serving code takes wall time only through [`clock::start`] — the
//! `no-raw-wall-clock` lint rule machine-checks that discipline.
//!
//! # Overhead contract
//!
//! Device-track emission is branch + slot-store only (no locks,
//! atomics, or allocation on the job path; see [`recorder`]); rings
//! never grow; histograms are `Copy` arrays. The declared hot regions
//! (GEMM kernel, worker drain loop) contain **no recorder calls at
//! all** and `dip analyze`'s hot-region pass keeps it that way.
//! Dropped events (ring wrap) are counted, surfaced, and fail the
//! trace↔ledger conservation audit rather than lying by omission.
//!
//! # Consuming traces
//!
//! [`Trace::validate`] checks well-formedness (monotone stamps,
//! nested spans, resolvable ids); [`Trace::counts`] feeds
//! [`crate::check::audit::audit_trace`], which ties event tallies to
//! the settled metrics ledger; [`Trace::chrome_json`] exports Chrome
//! trace-event JSON (`dip trace-export`) viewable in Perfetto;
//! [`drift::drift_report`] compares measured utilization and TFPU
//! against the [`crate::analytical`] closed forms;
//! [`critpath::attribute`] walks every device track and charges each
//! simulated cycle of the pool budget to exactly one of six causal
//! categories (double-entry, audited by
//! [`crate::check::audit::audit_critpath`]); [`whatif::what_if`]
//! replays that attribution under counterfactuals to price ROADMAP
//! optimizations (`dip profile`); and [`top::render_top`] renders the
//! `dip top` dashboard.
//!
//! [`MetricsSnapshot`]: crate::coordinator::MetricsSnapshot

pub mod clock;
pub mod critpath;
pub mod drift;
pub mod hist;
pub mod recorder;
pub mod top;
pub mod trace;
pub mod whatif;

pub use clock::Stopwatch;
pub use critpath::{attribute, Attribution, Categories, DeviceAttribution, WaveSummary};
pub use drift::{drift_report, DeviceDrift, DriftReport};
pub use hist::{Hist, HIST_BUCKETS};
pub use recorder::{DeviceObs, Event, EventKind, EventRing, ObsConfig, Recorder, NO_ID};
pub use top::{render_top, render_watch_tick, TopInputs};
pub use trace::{DeviceTrace, Trace, TraceCounts};
pub use whatif::{what_if, Counterfactual, WhatIfReport};
