//! Drift telemetry: measured utilization vs the analytical closed
//! forms.
//!
//! The paper's Section IV/V models predict per-tile latency,
//! throughput, and time-to-full-PE-utilization exactly; the recorder
//! measures what the simulated pool actually did. This module divides
//! one by the other so every bench run reports how far the *system*
//! (scheduling, installs, coalescing, streaming) drifts from the
//! *single-tile* closed form:
//!
//! * **Utilization drift** — measured `pe_active / (n² · cycles)` per
//!   device over the analytical single-tile utilization
//!   `n / latency_cycles(arch, n, s)`. Streaming long strips and
//!   coalescing installs amortize fill/drain, pushing the ratio
//!   *above* 1; install stalls and idle bubbles pull it below — so
//!   the ratio is a legibility number, not an error bar.
//! * **TFPU drift** — the first executed job's measured
//!   `tfpu_cycles` over the closed form `tfpu_cycles(arch, n)`
//!   (DiP: `n`, WS: `2n−1`). This one should sit at exactly 1.0 for
//!   full tiles; a deviation means the simulator and the model
//!   disagree about the paper's headline claim.

use super::trace::Trace;
use crate::analytical::{latency_cycles, tfpu_cycles, Arch};
use crate::jsonio::Json;

/// One device's measured-vs-analytical comparison.
#[derive(Debug, Clone, Copy)]
pub struct DeviceDrift {
    pub device: u64,
    pub jobs: u64,
    /// `pe_active / (n² · cycles)` over the device's whole run.
    pub measured_util: f64,
    /// `n / latency_cycles(arch, n, s)` — one full n×n tile.
    pub analytical_util: f64,
    /// `measured_util / analytical_util`.
    pub util_drift: f64,
    /// First executed job's `tfpu_cycles`.
    pub measured_tfpu: u64,
    /// `tfpu_cycles(arch, n)`.
    pub analytical_tfpu: u64,
    /// `measured_tfpu / analytical_tfpu`.
    pub tfpu_drift: f64,
}

/// Per-run drift report (rides `BENCH_serving.json` and `dip top`).
#[derive(Debug, Clone)]
pub struct DriftReport {
    pub arch: Arch,
    pub tile: usize,
    pub devices: Vec<DeviceDrift>,
    /// Mean util drift over devices that executed jobs.
    pub mean_util_drift: f64,
    /// Mean TFPU drift over devices that executed jobs.
    pub mean_tfpu_drift: f64,
}

/// Compare a trace's per-device measurements against the closed forms
/// for the pool's (arch, tile, mac_stages) configuration.
pub fn drift_report(trace: &Trace, arch: Arch, tile: usize, mac_stages: u64) -> DriftReport {
    let n = tile as u64;
    let analytical_util = n as f64 / latency_cycles(arch, n, mac_stages) as f64;
    let analytical_tfpu = tfpu_cycles(arch, n);
    let mut devices = Vec::with_capacity(trace.devices.len());
    for d in &trace.devices {
        let measured_util = d.utilization(tile);
        let measured_tfpu = d.first_tfpu.unwrap_or(0);
        devices.push(DeviceDrift {
            device: d.device,
            jobs: d.jobs,
            measured_util,
            analytical_util,
            util_drift: measured_util / analytical_util,
            measured_tfpu,
            analytical_tfpu,
            tfpu_drift: measured_tfpu as f64 / analytical_tfpu as f64,
        });
    }
    let active: Vec<DeviceDrift> = devices.iter().filter(|d| d.jobs > 0).copied().collect();
    let (mut mean_util_drift, mut mean_tfpu_drift) = (0.0, 0.0);
    if !active.is_empty() {
        mean_util_drift = active.iter().map(|d| d.util_drift).sum::<f64>() / active.len() as f64;
        mean_tfpu_drift = active.iter().map(|d| d.tfpu_drift).sum::<f64>() / active.len() as f64;
    }
    DriftReport { arch, tile, devices, mean_util_drift, mean_tfpu_drift }
}

impl DriftReport {
    /// JSON shape embedded in the BENCH trajectory files.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::str(self.arch.name())),
            ("tile", Json::num(self.tile as f64)),
            ("mean_util_drift", Json::num(self.mean_util_drift)),
            ("mean_tfpu_drift", Json::num(self.mean_tfpu_drift)),
            (
                "devices",
                Json::Arr(
                    self.devices
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("device", Json::num(d.device as f64)),
                                ("jobs", Json::num(d.jobs as f64)),
                                ("measured_util", Json::num(d.measured_util)),
                                ("analytical_util", Json::num(d.analytical_util)),
                                ("util_drift", Json::num(d.util_drift)),
                                ("measured_tfpu", Json::num(d.measured_tfpu as f64)),
                                ("analytical_tfpu", Json::num(d.analytical_tfpu as f64)),
                                ("tfpu_drift", Json::num(d.tfpu_drift)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::Hist;
    use crate::obs::trace::DeviceTrace;

    fn track(device: u64, jobs: u64, cycles: u64, pe_active: u64, tfpu: Option<u64>) -> DeviceTrace {
        DeviceTrace {
            device,
            events: Vec::new(),
            dropped: 0,
            cycles,
            jobs,
            rows: 0,
            pe_active,
            first_tfpu: tfpu,
            wait_hist: Hist::default(),
            install_hist: Hist::default(),
            kernel_hist: Hist::default(),
        }
    }

    #[test]
    fn drift_matches_hand_computed_closed_forms() {
        // DiP n=8, s=2: latency = 2n+s-2 = 16, tfpu = n = 8.
        // Device 0: one full 8-row tile with no install — cycles 16,
        // pe_active = 8*64 = 512 — measured util = 512/(64*16) = 0.5,
        // exactly the analytical single-tile utilization 8/16.
        let t = Trace {
            devices: vec![track(0, 1, 16, 512, Some(8)), track(1, 0, 0, 0, None)],
            ..Trace::default()
        };
        let r = drift_report(&t, Arch::Dip, 8, 2);
        assert_eq!(r.devices.len(), 2);
        let d0 = &r.devices[0];
        assert!((d0.measured_util - 0.5).abs() < 1e-12);
        assert!((d0.analytical_util - 0.5).abs() < 1e-12);
        assert!((d0.util_drift - 1.0).abs() < 1e-12);
        assert_eq!(d0.analytical_tfpu, 8);
        assert!((d0.tfpu_drift - 1.0).abs() < 1e-12);
        // Idle device excluded from the means.
        assert!((r.mean_util_drift - 1.0).abs() < 1e-12);
        assert!((r.mean_tfpu_drift - 1.0).abs() < 1e-12);
    }

    #[test]
    fn streaming_pushes_util_drift_above_one() {
        // DiP n=8, s=2, one job streaming 32 rows: cycles = n+rows+s-2
        // = 40, pe_active = 32*64 = 2048, util = 2048/(64*40) = 0.8 —
        // 1.6x the single-tile closed form.
        let t = Trace { devices: vec![track(0, 1, 40, 2048, Some(8))], ..Trace::default() };
        let r = drift_report(&t, Arch::Dip, 8, 2);
        assert!((r.devices[0].util_drift - 1.6).abs() < 1e-12);
    }

    #[test]
    fn drift_json_round_trips() {
        let t = Trace { devices: vec![track(0, 1, 16, 512, Some(8))], ..Trace::default() };
        let r = drift_report(&t, Arch::Ws, 8, 2);
        let back = Json::parse(&r.to_json().render()).unwrap();
        assert_eq!(back.get("arch").unwrap().as_str(), Some("WS"));
        assert_eq!(back.get("devices").unwrap().as_arr().unwrap().len(), 1);
        // WS closed forms: latency = 3n+s-3 = 23, tfpu = 2n-1 = 15.
        let d = &back.get("devices").unwrap().as_arr().unwrap()[0];
        assert_eq!(d.get("analytical_tfpu").unwrap().as_u64(), Some(15));
    }
}
