//! `dip top` — text dashboard over a settled run: per-device
//! utilization and drift, queue depths, tenant shares with histogram
//! queue-wait percentiles, the pool-wide latency summaries, and the
//! critical-path category split with its what-if speedup bounds.
//! `dip top --watch <secs>` renders per-tick counter deltas
//! ([`render_watch_tick`]) while the run is in flight, then the full
//! dashboard at the settled drain point.

use super::critpath::attribute;
use super::drift::drift_report;
use super::trace::Trace;
use super::whatif::what_if;
use crate::analytical::Arch;
use crate::bench_harness::report::{fnum, TextTable};
use crate::coordinator::{MetricsSnapshot, TenantSnapshot};

/// Everything the dashboard renders from.
pub struct TopInputs<'a> {
    pub trace: &'a Trace,
    pub snap: &'a MetricsSnapshot,
    pub tenants: &'a [TenantSnapshot],
    /// Queue depths sampled mid-flight (shard order = device index).
    pub queue_depths: &'a [usize],
    pub arch: Arch,
    pub tile: usize,
    pub mac_stages: u64,
}

/// Render the dashboard (pure string; `dip top --once` prints it).
pub fn render_top(inp: &TopInputs<'_>) -> String {
    let mut out = String::new();
    let drift = drift_report(inp.trace, inp.arch, inp.tile, inp.mac_stages);
    out.push_str(&format!(
        "dip top — {} pool, tile {}, {} devices\n\n",
        inp.arch.name(),
        inp.tile,
        inp.trace.devices.len()
    ));

    let mut devices = TextTable::new(vec![
        "device", "jobs", "rows", "cycles", "util %", "drift", "queue", "wait p50/p95/p99 ns",
    ]);
    for d in &inp.trace.devices {
        let dd = drift.devices.iter().find(|x| x.device == d.device);
        let depth = inp
            .queue_depths
            .get(usize::try_from(d.device).unwrap_or(usize::MAX))
            .map_or_else(|| "-".to_string(), |q| q.to_string());
        devices.row(vec![
            d.device.to_string(),
            d.jobs.to_string(),
            d.rows.to_string(),
            d.cycles.to_string(),
            fnum(d.utilization(inp.tile) * 100.0, 1),
            dd.map_or_else(|| "-".to_string(), |x| fnum(x.util_drift, 2)),
            depth,
            format!("{}/{}/{}", d.wait_hist.p50(), d.wait_hist.p95(), d.wait_hist.p99()),
        ]);
    }
    out.push_str(&devices.render());

    let total_served: u64 = inp.tenants.iter().map(|t| t.jobs_served).sum();
    let mut tenants = TextTable::new(vec![
        "tenant", "submitted", "served", "share %", "wait p50 ns", "wait p95 ns", "wait p99 ns",
    ]);
    for t in inp.tenants {
        let share = if total_served == 0 {
            0.0
        } else {
            t.jobs_served as f64 / total_served as f64
        };
        tenants.row(vec![
            t.tenant.to_string(),
            t.requests_submitted.to_string(),
            t.jobs_served.to_string(),
            fnum(share * 100.0, 1),
            t.wait_hist.p50().to_string(),
            t.wait_hist.p95().to_string(),
            t.wait_hist.p99().to_string(),
        ]);
    }
    out.push('\n');
    out.push_str(&tenants.render());

    let mut hists = TextTable::new(vec!["histogram", "unit", "p50/p95/p99 (n)"]);
    hists.row(vec!["queue wait".to_string(), "ns".to_string(), inp.trace.merged_wait_hist().summary()]);
    hists.row(vec![
        "install".to_string(),
        "cycles".to_string(),
        inp.trace.merged_install_hist().summary(),
    ]);
    hists.row(vec![
        "kernel".to_string(),
        "cycles".to_string(),
        inp.trace.merged_kernel_hist().summary(),
    ]);
    hists.row(vec!["step".to_string(), "ns".to_string(), inp.trace.step_hist.summary()]);
    hists.row(vec!["wave".to_string(), "ns".to_string(), inp.trace.wave_hist.summary()]);
    out.push('\n');
    out.push_str(&hists.render());

    // Critical-path attribution: where the cycle budget actually went,
    // and what each ROADMAP counterfactual could buy back.
    let attr = attribute(inp.trace);
    if attr.makespan > 0 {
        let mut cats = TextTable::new(vec!["critical path", "cycles", "% of budget"]);
        for (name, cycles) in attr.totals.named() {
            cats.row(vec![
                name.to_string(),
                cycles.to_string(),
                fnum(cycles as f64 / attr.budget as f64 * 100.0, 1),
            ]);
        }
        out.push('\n');
        out.push_str(&cats.render());
        let bounds = what_if(&attr);
        let mut wi = TextTable::new(vec!["what-if", "predicted makespan", "speedup <="]);
        for c in &bounds.counterfactuals {
            wi.row(vec![
                c.name.to_string(),
                c.predicted_makespan.to_string(),
                fnum(c.speedup_bound, 3),
            ]);
        }
        out.push('\n');
        out.push_str(&wi.render());
    }

    out.push_str(&format!(
        "\njobs {}  installs {}  skips {}  coalesced {}  reuse {:.0}%  steals {}  waves {}  \
         backpressure {}\nmean util drift {:.2}  mean tfpu drift {:.2}  (measured / analytical \
         closed form)\n",
        inp.snap.jobs_executed,
        inp.snap.weight_loads,
        inp.snap.weight_loads_skipped,
        inp.snap.jobs_coalesced,
        inp.snap.weight_reuse_rate() * 100.0,
        inp.snap.steals,
        inp.snap.waves,
        inp.snap.backpressure_events,
        drift.mean_util_drift,
        drift.mean_tfpu_drift,
    ));
    // Fault/recovery slice — only when something actually fired, so
    // fault-free runs keep their familiar layout.
    if inp.snap.faults_injected > 0 {
        out.push_str(&format!(
            "faults {}  failed {}  retried {}  abandoned {}  reclaimed {}  failed-cycles {}  \
             quarantines {}/{}  deaths {}\n",
            inp.snap.faults_injected,
            inp.snap.jobs_failed,
            inp.snap.jobs_retried,
            inp.snap.jobs_abandoned,
            inp.snap.jobs_reclaimed,
            inp.snap.failed_cycles,
            inp.snap.quarantines_entered,
            inp.snap.quarantines_exited,
            inp.snap.device_deaths,
        ));
    }
    out
}

/// One `--watch` refresh line set: the counter movement since the
/// previous tick (`delta = now.delta(&prev)`), plus instantaneous
/// queue depths. Rates use the tick's wall elapsed seconds.
pub fn render_watch_tick(
    tick: u64,
    delta: &MetricsSnapshot,
    queue_depths: &[usize],
    elapsed_s: f64,
) -> String {
    let rate = |v: u64| {
        if elapsed_s > 0.0 {
            fnum(v as f64 / elapsed_s, 1)
        } else {
            "-".to_string()
        }
    };
    let depths: Vec<String> = queue_depths.iter().map(|d| d.to_string()).collect();
    format!(
        "[tick {tick}] +jobs {} ({}/s)  +rows {} ({}/s)  +cycles {}  +installs {}  +skips {}  \
         +coalesced {}  +steals {}  +waves {}  +backpressure {}  queues [{}]\n",
        delta.jobs_executed,
        rate(delta.jobs_executed),
        delta.rows_streamed,
        rate(delta.rows_streamed),
        delta.sim_cycles,
        delta.weight_loads,
        delta.weight_loads_skipped,
        delta.jobs_coalesced,
        delta.steals,
        delta.waves,
        delta.backpressure_events,
        depths.join(" "),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::Hist;
    use crate::obs::trace::DeviceTrace;

    #[test]
    fn dashboard_renders_devices_tenants_and_histograms() {
        let mut wait = Hist::default();
        for v in [100u64, 200, 4000] {
            wait.record(v);
        }
        let trace = Trace {
            devices: vec![DeviceTrace {
                device: 0,
                events: Vec::new(),
                dropped: 0,
                cycles: 16,
                jobs: 1,
                rows: 8,
                pe_active: 512,
                first_tfpu: Some(8),
                wait_hist: wait,
                install_hist: Hist::default(),
                kernel_hist: Hist::default(),
            }],
            ..Trace::default()
        };
        let snap = MetricsSnapshot { jobs_executed: 1, ..MetricsSnapshot::default() };
        let tenants =
            vec![TenantSnapshot { tenant: 7, jobs_served: 1, wait_hist: wait, ..Default::default() }];
        let s = render_top(&TopInputs {
            trace: &trace,
            snap: &snap,
            tenants: &tenants,
            queue_depths: &[3],
            arch: Arch::Dip,
            tile: 8,
            mac_stages: 2,
        });
        assert!(s.contains("device"), "{s}");
        assert!(s.contains("50.0"), "device 0 utilization: {s}");
        assert!(s.contains("| 7 "), "tenant row: {s}");
        assert!(s.contains("queue wait"), "{s}");
        assert!(s.contains("mean util drift"), "{s}");
        // Share of the only tenant is 100%.
        assert!(s.contains("100.0"), "{s}");
        // No job events on the track: makespan 0, so the critical-path
        // and what-if tables are withheld rather than rendered empty.
        assert!(!s.contains("critical path"), "{s}");
    }

    #[test]
    fn dashboard_shows_fault_slice_only_when_faults_fired() {
        let trace = Trace::default();
        let inputs = |snap: &MetricsSnapshot| {
            render_top(&TopInputs {
                trace: &trace,
                snap,
                tenants: &[],
                queue_depths: &[0],
                arch: Arch::Dip,
                tile: 8,
                mac_stages: 2,
            })
        };
        let quiet = MetricsSnapshot::default();
        assert!(!inputs(&quiet).contains("faults"), "fault-free layout stays unchanged");
        let chaotic = MetricsSnapshot {
            faults_injected: 4,
            jobs_failed: 3,
            jobs_retried: 2,
            jobs_abandoned: 1,
            jobs_reclaimed: 5,
            failed_cycles: 30,
            quarantines_entered: 2,
            quarantines_exited: 1,
            device_deaths: 1,
            ..Default::default()
        };
        let s = inputs(&chaotic);
        assert!(s.contains("faults 4"), "{s}");
        assert!(s.contains("retried 2"), "{s}");
        assert!(s.contains("quarantines 2/1"), "{s}");
        assert!(s.contains("deaths 1"), "{s}");
    }

    #[test]
    fn dashboard_folds_in_attribution_and_what_if_bounds() {
        use crate::obs::recorder::{Event, EventKind};
        let mut d = DeviceTrace { device: 0, ..DeviceTrace::default() };
        d.events.push(Event::new(EventKind::Job, 0, 10));
        d.events.push(Event::new(EventKind::Install, 0, 2));
        let mut kernel = Event::new(EventKind::Kernel, 2, 8);
        kernel.rows = 4;
        d.events.push(kernel);
        let trace = Trace { devices: vec![d], ..Trace::default() };
        let snap = MetricsSnapshot::default();
        let s = render_top(&TopInputs {
            trace: &trace,
            snap: &snap,
            tenants: &[],
            queue_depths: &[0],
            arch: Arch::Dip,
            tile: 8,
            mac_stages: 2,
        });
        assert!(s.contains("critical path"), "{s}");
        assert!(s.contains("install"), "{s}");
        assert!(s.contains("what-if"), "{s}");
        assert!(s.contains("installs_hidden"), "{s}");
        assert!(s.contains("perfect_balance"), "{s}");
    }

    #[test]
    fn watch_tick_renders_deltas_and_rates() {
        let delta = MetricsSnapshot {
            jobs_executed: 6,
            rows_streamed: 48,
            sim_cycles: 100,
            steals: 1,
            ..Default::default()
        };
        let s = render_watch_tick(3, &delta, &[2, 0], 2.0);
        assert!(s.contains("[tick 3]"), "{s}");
        assert!(s.contains("+jobs 6 (3.0/s)"), "{s}");
        assert!(s.contains("+rows 48 (24.0/s)"), "{s}");
        assert!(s.contains("queues [2 0]"), "{s}");
        // Zero elapsed degrades rates to "-" instead of dividing.
        let s = render_watch_tick(0, &delta, &[], 0.0);
        assert!(s.contains("(-/s)"), "{s}");
    }
}
