//! Assembled traces: well-formedness validation, event counting for
//! the trace↔ledger audit, and Chrome trace-event JSON export.
//!
//! A [`Trace`] is the frozen output of a [`Recorder`](super::Recorder)
//! after the coordinator drained: the control track (submission,
//! backpressure, wave/session lifecycle, clocked by the control
//! sequence) plus one [`DeviceTrace`] track per worker (job spans with
//! nested install/kernel slices, clocked by cumulative simulated
//! cycles). Export renders devices as Perfetto tracks (`tid = device
//! index + 1`, control at `tid 0`) with installs vs compute as nested
//! slices under each job.

use super::hist::Hist;
use super::recorder::{Event, EventKind, NO_ID};
use crate::jsonio::Json;

/// One device's published track plus its utilization accounting.
#[derive(Debug, Clone, Default)]
pub struct DeviceTrace {
    pub device: u64,
    /// Events in ring (= emission) order.
    pub events: Vec<Event>,
    /// Events lost to ring overwrite (0 in a well-formed trace).
    pub dropped: u64,
    /// Final device-cycle clock (sum of executed run cycles,
    /// including charged install cycles).
    pub cycles: u64,
    pub jobs: u64,
    pub rows: u64,
    /// Active-PE cycle total over all executed jobs.
    pub pe_active: u64,
    /// Measured time-to-full-PE-utilization: `tfpu_cycles` of the
    /// device's first job (kernel-relative, like the closed form).
    pub first_tfpu: Option<u64>,
    pub wait_hist: Hist,
    pub install_hist: Hist,
    pub kernel_hist: Hist,
}

impl DeviceTrace {
    /// Measured utilization: active-PE cycles over `n² · cycles`.
    /// Streaming and coalescing push this *above* the single-tile
    /// closed form; install stalls pull it below.
    pub fn utilization(&self, n: usize) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.pe_active as f64 / ((n * n) as f64 * self.cycles as f64)
        }
    }
}

/// The full assembled trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Control-track events in sequence order.
    pub control_events: Vec<Event>,
    pub control_dropped: u64,
    /// Device tracks, sorted by device index.
    pub devices: Vec<DeviceTrace>,
    /// Serving step latency (wall ns).
    pub step_hist: Hist,
    /// Wave latency (wall ns).
    pub wave_hist: Hist,
}

/// Event tallies of a trace — the left-hand side of the trace↔ledger
/// conservation identities in [`crate::check::audit::audit_trace`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceCounts {
    pub submits: u64,
    pub enqueues: u64,
    pub backpressure: u64,
    pub pops: u64,
    pub steals: u64,
    pub jobs: u64,
    pub installs: u64,
    pub install_skips: u64,
    pub coalesced_skips: u64,
    pub kernels: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub wave_opens: u64,
    pub wave_closes: u64,
    pub session_joins: u64,
    pub session_leaves: u64,
    pub faults: u64,
    pub job_retries: u64,
    pub job_abandons: u64,
    pub device_quarantines: u64,
    pub device_revives: u64,
    /// Ring drops across every track (a drop voids conservation).
    pub dropped: u64,
}

impl Trace {
    fn all_events(&self) -> impl Iterator<Item = &Event> {
        self.control_events.iter().chain(self.devices.iter().flat_map(|d| d.events.iter()))
    }

    /// Tally every event by kind.
    pub fn counts(&self) -> TraceCounts {
        let mut c = TraceCounts {
            dropped: self.control_dropped + self.devices.iter().map(|d| d.dropped).sum::<u64>(),
            ..TraceCounts::default()
        };
        for ev in self.all_events() {
            match ev.kind {
                EventKind::Submit => c.submits += 1,
                EventKind::Enqueue => c.enqueues += 1,
                EventKind::Backpressure => c.backpressure += 1,
                EventKind::Pop => c.pops += 1,
                EventKind::Steal => c.steals += 1,
                EventKind::Job => c.jobs += 1,
                EventKind::Install => c.installs += 1,
                EventKind::InstallSkip => c.install_skips += 1,
                EventKind::CoalescedSkip => c.coalesced_skips += 1,
                EventKind::Kernel => c.kernels += 1,
                EventKind::CacheHit => c.cache_hits += 1,
                EventKind::CacheMiss => c.cache_misses += 1,
                EventKind::WaveOpen => c.wave_opens += 1,
                EventKind::WaveClose => c.wave_closes += 1,
                EventKind::SessionJoin => c.session_joins += 1,
                EventKind::SessionLeave => c.session_leaves += 1,
                EventKind::FaultInjected => c.faults += 1,
                EventKind::JobRetry => c.job_retries += 1,
                EventKind::JobAbandon => c.job_abandons += 1,
                EventKind::DeviceQuarantined => c.device_quarantines += 1,
                EventKind::DeviceRevived => c.device_revives += 1,
            }
        }
        c
    }

    /// Pool-wide queue-wait histogram (merged device hists).
    pub fn merged_wait_hist(&self) -> Hist {
        let mut h = Hist::default();
        for d in &self.devices {
            h.merge(&d.wait_hist);
        }
        h
    }

    /// Pool-wide install-cycle histogram.
    pub fn merged_install_hist(&self) -> Hist {
        let mut h = Hist::default();
        for d in &self.devices {
            h.merge(&d.install_hist);
        }
        h
    }

    /// Pool-wide kernel-cycle histogram.
    pub fn merged_kernel_hist(&self) -> Hist {
        let mut h = Hist::default();
        for d in &self.devices {
            h.merge(&d.kernel_hist);
        }
        h
    }

    /// Well-formedness: per-device cycle stamps monotone in ring
    /// order, install/kernel slices nested inside their job span, job
    /// spans disjoint, and causal ids resolving (device jobs only use
    /// tiles/tenants the control track enqueued; session leaves and
    /// wave closes match an open). Returns every violation found
    /// (empty = well-formed).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        // Control track: sequence stamps strictly increasing.
        for w in self.control_events.windows(2) {
            if w[1].cyc <= w[0].cyc {
                errs.push(format!(
                    "control sequence not increasing: {} at {} after {} at {}",
                    w[1].kind.name(),
                    w[1].cyc,
                    w[0].kind.name(),
                    w[0].cyc
                ));
            }
        }
        let enq_tiles: std::collections::HashSet<u64> = self
            .control_events
            .iter()
            .filter(|e| e.kind == EventKind::Enqueue)
            .map(|e| e.tile)
            .collect();
        let enq_tenants: std::collections::HashSet<u64> = self
            .control_events
            .iter()
            .filter(|e| e.kind == EventKind::Enqueue)
            .map(|e| e.tenant)
            .collect();
        let opened: std::collections::HashSet<u64> = self
            .control_events
            .iter()
            .filter(|e| e.kind == EventKind::WaveOpen)
            .map(|e| e.wave)
            .collect();
        let joined: std::collections::HashSet<u64> = self
            .control_events
            .iter()
            .filter(|e| e.kind == EventKind::SessionJoin)
            .map(|e| e.session)
            .collect();
        for ev in &self.control_events {
            match ev.kind {
                EventKind::WaveClose if !opened.contains(&ev.wave) => {
                    errs.push(format!("wave_close {} without wave_open", ev.wave));
                }
                EventKind::SessionLeave if !joined.contains(&ev.session) => {
                    errs.push(format!("session_leave {} without session_join", ev.session));
                }
                _ => {}
            }
        }
        for d in &self.devices {
            let label = format!("device {}", d.device);
            let mut last_cyc = 0u64;
            // Current job span, as (start, end).
            let mut job: Option<(u64, u64)> = None;
            for ev in &d.events {
                if ev.cyc < last_cyc {
                    errs.push(format!(
                        "{label}: cycle stamp regressed ({} at {} after {})",
                        ev.kind.name(),
                        ev.cyc,
                        last_cyc
                    ));
                }
                last_cyc = last_cyc.max(ev.cyc);
                match ev.kind {
                    EventKind::Job => {
                        if let Some((s, e)) = job {
                            if ev.cyc < e {
                                errs.push(format!(
                                    "{label}: job at {} overlaps job [{s}, {e})",
                                    ev.cyc
                                ));
                            }
                        }
                        job = Some((ev.cyc, ev.cyc + ev.dur));
                    }
                    EventKind::Install
                    | EventKind::Kernel
                    | EventKind::InstallSkip
                    | EventKind::CoalescedSkip => match job {
                        Some((s, e)) if ev.cyc >= s && ev.cyc + ev.dur <= e => {}
                        Some((s, e)) => errs.push(format!(
                            "{label}: {} [{}, {}) escapes job [{s}, {e})",
                            ev.kind.name(),
                            ev.cyc,
                            ev.cyc + ev.dur
                        )),
                        None => errs.push(format!(
                            "{label}: {} at {} outside any job span",
                            ev.kind.name(),
                            ev.cyc
                        )),
                    },
                    EventKind::Pop | EventKind::Steal | EventKind::CacheHit
                    | EventKind::CacheMiss | EventKind::FaultInjected
                    | EventKind::JobRetry | EventKind::JobAbandon => {}
                    other => {
                        errs.push(format!(
                            "{label}: control-track event {} on a device track",
                            other.name()
                        ));
                    }
                }
                // Causal ids must resolve against the control track.
                if ev.kind == EventKind::Job {
                    if ev.tile != NO_ID && !enq_tiles.contains(&ev.tile) {
                        errs.push(format!("{label}: job tile {:#x} never enqueued", ev.tile));
                    }
                    if ev.tenant != NO_ID && !enq_tenants.contains(&ev.tenant) {
                        errs.push(format!("{label}: job tenant {} never enqueued", ev.tenant));
                    }
                }
            }
        }
        errs
    }

    /// Chrome trace-event JSON (the `{"traceEvents": [...]}` object
    /// format). Open in Perfetto / `chrome://tracing`: `tid 0` is the
    /// coordinator control track, `tid N+1` is device `N`; job spans
    /// contain their install/kernel slices, and `handoff` flow arrows
    /// link each submit → pop/steal → job chain across tracks (so a
    /// stolen job visibly departs from the submitting control event to
    /// the thief's track instead of appearing as disconnected dots).
    /// `ts` is the primary deterministic clock (cycles / control
    /// sequence); wall ns ride in `args`.
    pub fn chrome_json(&self) -> Json {
        let mut evs: Vec<Json> = Vec::new();
        let meta = |name: &str, tid: u64, value: &str| {
            Json::obj(vec![
                ("name", Json::str(name)),
                ("ph", Json::str("M")),
                ("pid", Json::num(0)),
                ("tid", Json::num(tid as f64)),
                ("args", Json::obj(vec![("name", Json::str(value))])),
            ])
        };
        evs.push(meta("process_name", 0, "dip"));
        evs.push(meta("thread_name", 0, "coordinator"));
        for d in &self.devices {
            evs.push(meta("thread_name", d.device + 1, &format!("device {}", d.device)));
        }
        for ev in &self.control_events {
            evs.push(Self::event_json(ev, 0));
        }
        for d in &self.devices {
            for ev in &d.events {
                evs.push(Self::event_json(ev, d.device + 1));
            }
        }
        self.flow_events(&mut evs);
        Json::obj(vec![
            ("traceEvents", Json::Arr(evs)),
            ("displayTimeUnit", Json::str("ns")),
        ])
    }

    /// Emit `submit → pop/steal → job` flow chains. Each control-track
    /// enqueue (bound to the most recent preceding submit) opens a
    /// chain; device jobs consume chains FIFO by `(tenant, tile)` —
    /// rows don't participate because coalesced batches execute with
    /// different row counts than were enqueued. Each matched job also
    /// consumes the earliest unconsumed pop/steal instant on its own
    /// track as the flow's middle step (a coalesced batch has one pop
    /// for several jobs, so later jobs flow straight submit → job).
    fn flow_events(&self, evs: &mut Vec<Json>) {
        struct Chain {
            submit_cyc: u64,
            tenant: u64,
            tile: u64,
            matched: bool,
        }
        let mut chains: Vec<Chain> = Vec::new();
        let mut last_submit: Option<u64> = None;
        for ev in &self.control_events {
            match ev.kind {
                EventKind::Submit => last_submit = Some(ev.cyc),
                EventKind::Enqueue => {
                    if let Some(submit_cyc) = last_submit {
                        chains.push(Chain {
                            submit_cyc,
                            tenant: ev.tenant,
                            tile: ev.tile,
                            matched: false,
                        });
                    }
                }
                _ => {}
            }
        }
        let mut next_id = 0u64;
        for d in &self.devices {
            let tid = d.device + 1;
            let mut pops: std::collections::VecDeque<u64> = std::collections::VecDeque::new();
            for ev in &d.events {
                match ev.kind {
                    EventKind::Pop | EventKind::Steal => pops.push_back(ev.cyc),
                    EventKind::Job => {
                        let Some(chain) = chains
                            .iter_mut()
                            .find(|c| !c.matched && c.tenant == ev.tenant && c.tile == ev.tile)
                        else {
                            continue;
                        };
                        chain.matched = true;
                        let id = next_id;
                        next_id += 1;
                        evs.push(Self::flow_json("s", id, 0, chain.submit_cyc));
                        if let Some(pop_cyc) = pops.pop_front() {
                            evs.push(Self::flow_json("t", id, tid, pop_cyc));
                        }
                        evs.push(Self::flow_json("f", id, tid, ev.cyc));
                    }
                    _ => {}
                }
            }
        }
    }

    fn flow_json(ph: &str, id: u64, tid: u64, ts: u64) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("name", Json::str("handoff")),
            ("cat", Json::str("flow")),
            ("ph", Json::str(ph)),
            ("id", Json::num(id as f64)),
            ("pid", Json::num(0)),
            ("tid", Json::num(tid as f64)),
            ("ts", Json::num(ts as f64)),
        ];
        if ph == "f" {
            // Bind to the enclosing slice's start, so the arrow lands
            // on the job span instead of floating.
            fields.push(("bp", Json::str("e")));
        }
        Json::obj(fields)
    }

    fn event_json(ev: &Event, tid: u64) -> Json {
        let mut args: Vec<(&str, Json)> = vec![("wall_ns", Json::num(ev.wall_ns as f64))];
        if ev.rows > 0 {
            args.push(("rows", Json::num(ev.rows as f64)));
        }
        if ev.tenant != NO_ID {
            args.push(("tenant", Json::num(ev.tenant as f64)));
        }
        if ev.tile != NO_ID {
            args.push(("tile", Json::str(format!("{:#018x}", ev.tile))));
        }
        if ev.request != NO_ID {
            args.push(("request", Json::num(ev.request as f64)));
        }
        if ev.wave != NO_ID {
            args.push(("wave", Json::num(ev.wave as f64)));
        }
        if ev.session != NO_ID {
            args.push(("session", Json::num(ev.session as f64)));
        }
        let mut fields: Vec<(&str, Json)> = vec![
            ("name", Json::str(ev.kind.name())),
            ("pid", Json::num(0)),
            ("tid", Json::num(tid as f64)),
            ("ts", Json::num(ev.cyc as f64)),
            ("args", Json::obj(args)),
        ];
        if ev.kind.is_span() {
            fields.push(("ph", Json::str("X")));
            fields.push(("dur", Json::num(ev.dur as f64)));
        } else {
            fields.push(("ph", Json::str("i")));
            fields.push(("s", Json::str("t")));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::{Event, EventKind};

    fn dev_track(events: Vec<Event>) -> DeviceTrace {
        DeviceTrace {
            device: 0,
            events,
            dropped: 0,
            cycles: 0,
            jobs: 0,
            rows: 0,
            pe_active: 0,
            first_tfpu: None,
            wait_hist: Hist::default(),
            install_hist: Hist::default(),
            kernel_hist: Hist::default(),
        }
    }

    fn well_formed() -> Trace {
        let tile = 0xAB;
        let enq = Event { tile, tenant: 0, ..Event::new(EventKind::Enqueue, 0, 0) };
        let mut t = Trace {
            control_events: vec![
                Event { request: 1, tenant: 0, ..Event::new(EventKind::Submit, 0, 0) },
                Event { cyc: 1, ..enq },
                Event { cyc: 2, ..enq },
            ],
            ..Trace::default()
        };
        let job = |cyc, dur| Event { tile, tenant: 0, ..Event::new(EventKind::Job, cyc, dur) };
        t.devices.push(dev_track(vec![
            Event::new(EventKind::Pop, 0, 0),
            Event::new(EventKind::CacheMiss, 0, 0),
            job(0, 23),
            Event::new(EventKind::Install, 0, 7),
            Event::new(EventKind::Kernel, 7, 16),
            job(23, 12),
            Event::new(EventKind::InstallSkip, 23, 0),
            Event::new(EventKind::Kernel, 23, 12),
        ]));
        t
    }

    #[test]
    fn well_formed_trace_validates_clean_and_counts_partition() {
        let t = well_formed();
        assert_eq!(t.validate(), Vec::<String>::new());
        let c = t.counts();
        assert_eq!(c.submits, 1);
        assert_eq!(c.enqueues, 2);
        assert_eq!(c.jobs, 2);
        assert_eq!(c.installs, 1);
        assert_eq!(c.install_skips, 1);
        assert_eq!(c.kernels, 2);
        assert_eq!(c.pops, 1);
        assert_eq!(c.cache_misses, 1);
        assert_eq!(c.dropped, 0);
        assert_eq!(c.installs + c.install_skips + c.coalesced_skips, c.jobs);
    }

    #[test]
    fn validator_catches_cycle_regression() {
        let mut t = well_formed();
        t.devices[0].events[5].cyc = 3; // second job stamped before the first ended
        let errs = t.validate();
        assert!(
            errs.iter().any(|e| e.contains("overlaps job")),
            "want an overlap violation, got {errs:?}"
        );
        t.devices[0].events[5].cyc = 23;
        t.devices[0].events[7].cyc = 1; // kernel stamped before its job
        let errs = t.validate();
        assert!(errs.iter().any(|e| e.contains("cycle stamp regressed")), "{errs:?}");
    }

    #[test]
    fn validator_catches_escaped_slice_and_unresolved_ids() {
        let mut t = well_formed();
        t.devices[0].events[4].dur = 40; // kernel runs past its job span
        assert!(t.validate().iter().any(|e| e.contains("escapes job")));

        let mut t = well_formed();
        t.devices[0].events[2].tile = 0xDEAD; // job against a never-enqueued tile
        assert!(t.validate().iter().any(|e| e.contains("never enqueued")));

        let mut t = well_formed();
        t.control_events.push(Event {
            wave: 9,
            ..Event::new(EventKind::WaveClose, 99, 0)
        });
        assert!(t.validate().iter().any(|e| e.contains("without wave_open")));
    }

    #[test]
    fn fault_events_validate_count_and_export() {
        let mut t = well_formed();
        let tile = 0xAB;
        // Fault lifecycle on the device track: injection/retry/abandon
        // are free instants (a failed attempt emits no job span).
        let inst = |k, cyc| Event { tile, tenant: 0, ..Event::new(k, cyc, 0) };
        t.devices[0].events.push(inst(EventKind::FaultInjected, 35));
        t.devices[0].events.push(inst(EventKind::JobRetry, 35));
        t.devices[0].events.push(inst(EventKind::JobAbandon, 35));
        // Quarantine transitions live on the control track, with the
        // subject device as the causal id.
        t.control_events
            .push(Event { device: 0, ..Event::new(EventKind::DeviceQuarantined, 3, 0) });
        t.control_events.push(Event { device: 0, ..Event::new(EventKind::DeviceRevived, 4, 0) });
        assert_eq!(t.validate(), Vec::<String>::new());
        let c = t.counts();
        assert_eq!(c.faults, 1);
        assert_eq!(c.job_retries, 1);
        assert_eq!(c.job_abandons, 1);
        assert_eq!(c.device_quarantines, 1);
        assert_eq!(c.device_revives, 1);
        // The Perfetto export renders them under their stable names.
        let rendered = t.chrome_json().render();
        for name in
            ["fault_injected", "job_retry", "job_abandon", "device_quarantined", "device_revived"]
        {
            assert!(rendered.contains(name), "export missing {name}");
        }
        // A quarantine stamped onto a device track is misplaced.
        let mut bad = well_formed();
        bad.devices[0].events.push(Event::new(EventKind::DeviceQuarantined, 40, 0));
        assert!(bad.validate().iter().any(|e| e.contains("control-track event")));
    }

    #[test]
    fn chrome_export_is_valid_json_with_tracks_and_nested_slices() {
        let t = well_formed();
        let rendered = t.chrome_json().render();
        let back = Json::parse(&rendered).expect("export must be valid JSON");
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 metadata (process + control + 1 device) + 3 control +
        // 8 device + 5 flow (s/t/f for job 1, s/f for job 2).
        assert_eq!(evs.len(), 19);
        let spans: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        assert_eq!(spans.len(), 5); // 2 jobs + 1 install + 2 kernels
        let job = spans
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("job"))
            .unwrap();
        assert_eq!(job.get("tid").unwrap().as_u64(), Some(1));
        assert_eq!(job.get("ts").unwrap().as_u64(), Some(0));
        assert_eq!(job.get("dur").unwrap().as_u64(), Some(23));
        // Instants carry the scope field Perfetto expects.
        let inst = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("install_skip"))
            .unwrap();
        assert_eq!(inst.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(inst.get("s").unwrap().as_str(), Some("t"));
    }

    #[test]
    fn flow_events_link_submit_pop_job_across_tracks() {
        let t = well_formed();
        let back = Json::parse(&t.chrome_json().render()).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        let flows: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("cat").and_then(Json::as_str) == Some("flow"))
            .collect();
        // Two enqueued chains: the first job consumes the track's one
        // pop (s → t → f); the second flows straight submit → job.
        assert_eq!(flows.len(), 5);
        let ph = |f: &Json| f.get("ph").and_then(Json::as_str).unwrap().to_string();
        let phases: Vec<String> = flows.iter().map(|f| ph(f)).collect();
        assert_eq!(phases, ["s", "t", "f", "s", "f"]);
        // Starts sit on the control track at the submit's stamp;
        // steps and finishes sit on the device track.
        for f in &flows {
            let tid = f.get("tid").unwrap().as_u64().unwrap();
            if ph(f) == "s" {
                assert_eq!(tid, 0);
                assert_eq!(f.get("ts").unwrap().as_u64(), Some(0));
            } else {
                assert_eq!(tid, 1);
            }
            if ph(f) == "f" {
                assert_eq!(f.get("bp").and_then(Json::as_str), Some("e"));
            }
        }
        // The two chains carry distinct flow ids.
        let ids: std::collections::HashSet<u64> =
            flows.iter().filter_map(|f| f.get("id").and_then(Json::as_u64)).collect();
        assert_eq!(ids.len(), 2);
        // The second chain's finish lands at the second job's start.
        assert_eq!(flows[4].get("ts").unwrap().as_u64(), Some(23));
    }
}
