//! Property-based invariant sweeps (seeded randomized testing; the
//! offline crate set has no proptest, so cases are generated with a
//! deterministic xorshift generator — every failure is reproducible
//! from the printed seed).
//!
//! Invariants covered (DESIGN.md §8):
//!  * permutation is a bijection and self-inverting,
//!  * DiP sim == WS sim == i32 reference matmul, bit-exact, for random
//!    sizes / row counts / pipeline depths,
//!  * per-tile cycle counts equal eqs (1)/(5) and TFPU eqs (4)/(7),
//!  * tiling reassembly equals the whole-matrix reference for ragged
//!    shapes,
//!  * coordinator responses are exact and order-independent,
//!  * `submit_batched` ≡ per-request `submit` ≡ the i32 reference
//!    matmul for ragged shapes, across device counts, architectures,
//!    queue depths, placement policies, tenants, and work-stealing
//!    on/off,
//!  * `ShardedQueue` loses and duplicates nothing under randomized
//!    concurrent push/pop/steal/close interleavings, and the
//!    `MAX_FRONT_SKIPS` anti-starvation bound holds with stealing
//!    enabled,
//!  * the serving strip fan-out (`submit_strips_as`) ≡ `submit` ≡ the
//!    reference for ragged shapes,
//!  * a randomized autoregressive decode trace is bit-exact with the
//!    activation cache on vs off (and strictly cheaper with it on),
//!  * a randomized multi-session trace through the continuous-batching
//!    wave scheduler (mid-flight joins, staggered leaves, row budgets)
//!    is bit-exact with per-session decode while performing strictly
//!    fewer weight-tile installs and streaming strictly fewer rows,
//!  * a randomized wave-mix run's flight-recorder trace is well-formed
//!    (spans nest, causal ids resolve, per-device cycle stamps are
//!    monotone) and its event tallies conserve exactly against the
//!    settled metrics ledger,
//!  * the critical-path profiler's attribution over a randomized
//!    wave-mix trace partitions the device-cycle budget exactly
//!    (every category sums back to the span, double-entry against the
//!    settled ledger via `audit_critpath`), and every what-if
//!    counterfactual is a true lower bound: predicted makespan never
//!    exceeds the measured one, so speedup bounds never dip below 1,
//!  * the activation-strip LRU never exceeds its capacity bound and
//!    hits are pointer-shared,
//!  * the analyzer's value-range pass is sound: random layer configs
//!    executed concretely (the stage graph's widened matmuls, causal
//!    masking, and `narrow` requantization) keep every intermediate
//!    i32 inside the interval `check::analyze::ranges` infers for its
//!    stage, and `max_safe_seq_len` sits exactly on the fit/overflow
//!    frontier.

use std::sync::Arc;

use dip_core::analytical::{latency_cycles, Arch};
use dip_core::arch::permute::{permute, unpermute};
use dip_core::arch::{dip::DipArray, ws::WsArray, SystolicArray};
use dip_core::bench_harness::scenarios::{
    assert_cached_strictly_cheaper, assert_waved_strictly_cheaper, run_decode_mix, run_wave_mix,
    run_wave_mix_per_session, run_wave_mix_with_faults, DecodeMix, WaveMix, WaveSessionSpec,
};
use dip_core::check::audit::{audit_critpath, audit_trace};
use dip_core::coordinator::{
    Coordinator, CoordinatorConfig, DeviceConfig, Metrics, PlacementPolicy, ShardedQueue,
    TenantId, DEFAULT_TENANT, MAX_FRONT_SKIPS,
};
use dip_core::matrix::{random_i8, Mat};
use dip_core::obs::{attribute, what_if};
use dip_core::serving::{ActStripCache, LayerDims, WavePolicy};
use dip_core::tiling::schedule::{run_tiled_matmul, TilingConfig, WeightLoadPolicy};

/// Deterministic case generator.
struct Gen(u64);

impl Gen {
    fn next(&mut self) -> u64 {
        let mut s = self.0;
        s ^= s >> 12;
        s ^= s << 25;
        s ^= s >> 27;
        self.0 = s;
        s.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

#[test]
fn prop_permutation_bijective_and_inverse() {
    let mut g = Gen(0xBEEF);
    for case in 0..200 {
        let n = g.range(1, 33) as usize;
        let cols = g.range(1, 33) as usize;
        let seed = g.next();
        let w = random_i8(n, cols, seed);
        let wp = permute(&w);
        assert_eq!(unpermute(&wp).as_slice(), w.as_slice(), "case {case} n={n} cols={cols} seed={seed}");
        // Bijection: multiset of elements preserved per column.
        for c in 0..cols {
            let mut a: Vec<i8> = (0..n).map(|r| w.get(r, c)).collect();
            let mut b: Vec<i8> = (0..n).map(|r| wp.get(r, c)).collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "case {case} column {c}");
        }
    }
}

#[test]
fn prop_sims_equal_reference_matmul() {
    let mut g = Gen(0xC0FFEE);
    for case in 0..60 {
        let n = g.range(1, 24) as usize;
        let rows = g.range(1, 40) as usize;
        let s = g.range(1, 3);
        let seed = g.next();
        let w = random_i8(n, n, seed);
        let x = random_i8(rows, n, seed + 1);
        let expect = x.widen().matmul(&w.widen());

        let mut dip = DipArray::new(n, s);
        dip.load_weights(&w);
        assert_eq!(dip.run_tile(&x).outputs, expect, "DiP case {case} n={n} rows={rows} s={s} seed={seed}");

        let mut ws = WsArray::new(n, s);
        ws.load_weights(&w);
        assert_eq!(ws.run_tile(&x).outputs, expect, "WS case {case} n={n} rows={rows} s={s} seed={seed}");
    }
}

#[test]
fn prop_kernel_matches_register_transfer_path() {
    // The two-path contract of `arch`: the derotated-GEMM kernel path
    // (`run_tile`) and the legacy wavefront path (`run_tile_legacy`)
    // must be bit-identical to the register-transfer reference
    // (`run_tile_traced`) in every observable — outputs, cycles, TFPU,
    // weight-load cycles, total ops, and each EventCounts field — for
    // n across 4..=64 and rows below, at, and far above n, on DiP and
    // WS alike.
    let mut g = Gen(0xDE07A7ED);
    for case in 0..24 {
        let n = g.range(4, 64) as usize;
        let s = g.range(1, 2);
        let rows = match case % 3 {
            0 => g.range(1, n as u64 - 1) as usize, // rows < n
            1 => n,                                 // rows = n
            _ => n * g.range(3, 5) as usize,        // rows >> n
        };
        let seed = g.next();
        let w = random_i8(n, n, seed);
        let x = random_i8(rows, n, seed + 1);
        let ctx = format!("case {case} n={n} s={s} rows={rows} seed={seed}");

        let mut dip = DipArray::new(n, s);
        dip.load_weights(&w);
        let fast = dip.run_tile(&x);
        let legacy = dip.run_tile_legacy(&x);
        let (slow, _) = dip.run_tile_traced(&x);
        assert_eq!(fast.outputs, slow.outputs, "DiP kernel outputs {ctx}");
        assert_eq!(fast.stats, slow.stats, "DiP kernel stats {ctx}");
        assert_eq!(legacy.outputs, slow.outputs, "DiP legacy outputs {ctx}");
        assert_eq!(legacy.stats, slow.stats, "DiP legacy stats {ctx}");

        let mut ws = WsArray::new(n, s);
        ws.load_weights(&w);
        let fast = ws.run_tile(&x);
        let legacy = ws.run_tile_legacy(&x);
        let (slow, _) = ws.run_tile_traced(&x);
        assert_eq!(fast.outputs, slow.outputs, "WS kernel outputs {ctx}");
        assert_eq!(fast.stats, slow.stats, "WS kernel stats {ctx}");
        assert_eq!(legacy.outputs, slow.outputs, "WS legacy outputs {ctx}");
        assert_eq!(legacy.stats, slow.stats, "WS legacy stats {ctx}");
    }
}

#[test]
fn prop_run_tile_batch_equals_sequential_runs() {
    // The batched entry point is defined as exactly sequential
    // `run_tile`s: same outputs, same per-run stats, for random batch
    // sizes and strip heights on both architectures.
    let mut g = Gen(0xBA7C8);
    for case in 0..20 {
        let n = g.range(2, 24) as usize;
        let s = g.range(1, 2);
        let batch = g.range(1, 6) as usize;
        let seed = g.next();
        let w = random_i8(n, n, seed);
        let xs: Vec<Arc<dip_core::Mat<i8>>> = (0..batch)
            .map(|i| Arc::new(random_i8(g.range(1, 3 * n as u64) as usize, n, seed + 1 + i as u64)))
            .collect();
        for arch in [Arch::Dip, Arch::Ws] {
            let mut batched: Box<dyn SystolicArray> = match arch {
                Arch::Dip => Box::new(DipArray::new(n, s)),
                Arch::Ws => Box::new(WsArray::new(n, s)),
            };
            let mut sequential: Box<dyn SystolicArray> = match arch {
                Arch::Dip => Box::new(DipArray::new(n, s)),
                Arch::Ws => Box::new(WsArray::new(n, s)),
            };
            batched.load_weights(&w);
            sequential.load_weights(&w);
            let runs = batched.run_tile_batch(&xs);
            assert_eq!(runs.len(), xs.len());
            for (i, (x, run)) in xs.iter().zip(runs).enumerate() {
                let solo = sequential.run_tile(x);
                assert_eq!(run.outputs, solo.outputs, "{arch:?} case {case} strip {i}");
                assert_eq!(run.stats, solo.stats, "{arch:?} case {case} strip {i}");
            }
        }
    }
}

#[test]
fn prop_coalesced_device_batch_matches_sequential_ledger() {
    // Device-level tile coalescing: for random same-tile batches the
    // batched execution must reproduce the sequential execution's
    // outputs, per-request stats, and the full install/skip cycle
    // ledger (one install charge, N-1 skips) on both architectures.
    use dip_core::coordinator::{Device, Job};
    use dip_core::coordinator::{MatmulResponse, ReqState, SubRequest};
    use dip_core::fault::FleetError;
    use std::sync::mpsc::{channel, Receiver};
    use std::time::Instant;

    type Resp = Result<MatmulResponse, FleetError>;

    let mut g = Gen(0xC0A1E5CE);
    for case in 0..12 {
        let tile = [4usize, 8, 16][g.range(0, 2) as usize];
        let arch = if g.next() % 2 == 0 { Arch::Dip } else { Arch::Ws };
        let batch = g.range(2, 6) as usize;
        let seed = g.next();
        let w = Arc::new(random_i8(tile, tile, seed));
        let tile_id = w.content_hash();
        let xs: Vec<dip_core::Mat<i8>> = (0..batch)
            .map(|i| random_i8(g.range(1, 2 * tile as u64) as usize, tile, seed + 1 + i as u64))
            .collect();
        let job_for = |x: &dip_core::Mat<i8>| -> (Job, Receiver<Resp>) {
            let (tx, rx) = channel();
            let req = Arc::new(ReqState::new(
                x.rows(),
                tile,
                tile,
                1,
                vec![SubRequest { id: 0, row0: 0, rows: x.rows(), tx }],
            ));
            let job = Job {
                req,
                w_tile: Arc::clone(&w),
                x_strip: Arc::new(x.clone()),
                r0: 0,
                c0: 0,
                tile_id,
                tenant: DEFAULT_TENANT,
                enqueued_at: Instant::now(),
                attempt: 0,
            };
            (job, rx)
        };
        let cfg = DeviceConfig { arch, tile, mac_stages: 2, ..Default::default() };

        let m_seq = Arc::new(Metrics::default());
        let mut dev_seq = Device::new(cfg, 0, m_seq.clone());
        let seq: Vec<MatmulResponse> = xs
            .iter()
            .map(|x| {
                let (job, rx) = job_for(x);
                dev_seq.execute(job);
                rx.try_recv().expect("sequential response").expect("fault-free job cannot fail")
            })
            .collect();

        let m_bat = Arc::new(Metrics::default());
        let mut dev_bat = Device::new(cfg, 0, m_bat.clone());
        let (jobs, rxs): (Vec<Job>, Vec<Receiver<Resp>>) =
            xs.iter().map(|x| job_for(x)).unzip();
        dev_bat.execute_batch(jobs);

        let ctx = format!("case {case} arch={arch:?} tile={tile} batch={batch} seed={seed}");
        for ((x, s_resp), rx) in xs.iter().zip(&seq).zip(rxs) {
            let b_resp =
                rx.try_recv().expect("batched response").expect("fault-free job cannot fail");
            assert_eq!(b_resp.out, s_resp.out, "{ctx}");
            assert_eq!(b_resp.out, x.widen().matmul(&w.widen()), "{ctx}");
            assert_eq!(b_resp.stats, s_resp.stats, "{ctx}");
        }
        let (s, b) = (m_seq.snapshot(), m_bat.snapshot());
        assert_eq!(b.weight_loads, 1, "{ctx}");
        assert_eq!(b.weight_loads_skipped, batch as u64 - 1, "{ctx}");
        assert_eq!(b.jobs_coalesced, batch as u64 - 1, "{ctx}");
        assert_eq!(b.weight_loads, s.weight_loads, "{ctx}");
        assert_eq!(b.weight_loads_skipped, s.weight_loads_skipped, "{ctx}");
        assert_eq!(b.weight_load_cycles_saved, s.weight_load_cycles_saved, "{ctx}");
        // Double-entry: the one install charges exactly the closed-form
        // load cost, and each skip credits the same amount — the
        // identities check::audit enforces on settled coordinators.
        let per_load = dip_core::check::audit::per_load_cycles(arch, tile);
        assert_eq!(b.weight_load_cycles_charged, per_load, "{ctx}");
        assert_eq!(b.weight_load_cycles_charged, s.weight_load_cycles_charged, "{ctx}");
        assert_eq!(b.weight_load_cycles_saved, (batch as u64 - 1) * per_load, "{ctx}");
        assert_eq!(b.sim_cycles, s.sim_cycles, "{ctx}");
        assert_eq!(b.mac_ops, s.mac_ops, "{ctx}");
        assert_eq!(b.rows_streamed, s.rows_streamed, "{ctx}");
        assert_eq!(b.requests_completed, s.requests_completed, "{ctx}");
    }
}

#[test]
fn prop_single_tile_latency_matches_equations() {
    let mut g = Gen(0xA11CE);
    for case in 0..40 {
        let n = g.range(2, 48) as usize;
        let s = g.range(1, 3);
        let seed = g.next();
        let w = random_i8(n, n, seed);
        let x = random_i8(n, n, seed + 1);

        let mut dip = DipArray::new(n, s);
        dip.load_weights(&w);
        assert_eq!(
            dip.run_tile(&x).stats.cycles,
            latency_cycles(Arch::Dip, n as u64, s),
            "DiP case {case} n={n} s={s}"
        );

        let mut ws = WsArray::new(n, s);
        ws.load_weights(&w);
        assert_eq!(
            ws.run_tile(&x).stats.cycles,
            latency_cycles(Arch::Ws, n as u64, s),
            "WS case {case} n={n} s={s}"
        );
    }
}

#[test]
fn prop_tfpu_matches_equations_under_streaming() {
    let mut g = Gen(0x7F9);
    for _ in 0..25 {
        let n = g.range(2, 24) as usize;
        let seed = g.next();
        let w = random_i8(n, n, seed);
        let x = random_i8(4 * n, n, seed + 1);

        let mut dip = DipArray::new(n, 2);
        dip.load_weights(&w);
        assert_eq!(dip.run_tile(&x).stats.tfpu_cycles, n as u64);

        let mut ws = WsArray::new(n, 2);
        ws.load_weights(&w);
        assert_eq!(ws.run_tile(&x).stats.tfpu_cycles, (2 * n - 1) as u64);
    }
}

#[test]
fn prop_tiled_matmul_ragged_shapes_exact() {
    let mut g = Gen(0xD1CE);
    for case in 0..30 {
        let m = g.range(1, 70) as usize;
        let nd = g.range(1, 70) as usize;
        let k = g.range(1, 70) as usize;
        let tile = [4usize, 8, 16][g.range(0, 2) as usize];
        let arch = if g.next() % 2 == 0 { Arch::Dip } else { Arch::Ws };
        let policy = if g.next() % 2 == 0 {
            WeightLoadPolicy::Overlapped
        } else {
            WeightLoadPolicy::Blocking
        };
        let seed = g.next();
        let x = random_i8(m, nd, seed);
        let w = random_i8(nd, k, seed + 1);
        let cfg = TilingConfig { tile, arch, mac_stages: 2, weight_load: policy };
        let (got, cost) = run_tiled_matmul(&x, &w, &cfg);
        assert_eq!(
            got,
            x.widen().matmul(&w.widen()),
            "case {case} m={m} n={nd} k={k} tile={tile} arch={arch:?} seed={seed}"
        );
        assert_eq!(cost.m2_tiles as usize, nd.div_ceil(tile) * k.div_ceil(tile));
    }
}

#[test]
fn prop_coordinator_exact_under_concurrency() {
    let mut g = Gen(0x5EED);
    for round in 0..6 {
        let cfg = CoordinatorConfig {
            devices: g.range(1, 6) as usize,
            device: DeviceConfig { arch: Arch::Dip, tile: 8, mac_stages: 2, ..Default::default() },
            queue_depth: g.range(1, 16) as usize,
            work_stealing: g.next() % 2 == 0,
            placement: if g.next() % 2 == 0 {
                PlacementPolicy::HeatAware
            } else {
                PlacementPolicy::HashMod
            },
        };
        let coord = Coordinator::new(cfg);
        let nd = g.range(1, 4) as usize * 8;
        let k = g.range(1, 4) as usize * 8;
        let w = random_i8(nd, k, g.next());
        let cases: Vec<(Mat<i8>, _)> = (0..12)
            .map(|_| {
                let m = g.range(1, 30) as usize;
                let x = random_i8(m, nd, g.next());
                // Random tenants: fairness lanes must never affect
                // results, only ordering.
                let h = coord.submit_as(g.range(0, 3) as TenantId, x.clone(), w.clone());
                (x, h)
            })
            .collect();
        for (x, h) in cases {
            assert_eq!(
                h.wait().out,
                x.widen().matmul(&w.widen()),
                "round {round} devices={} q={}",
                cfg.devices,
                cfg.queue_depth
            );
        }
        // Settle, then hold the randomized run to the double-entry
        // ledger identities: every round, every device/queue/stealing
        // shape must leave a balanced ledger behind.
        let (m, audit) = coord.shutdown_audited();
        audit.assert_balanced();
        assert_eq!(m.requests_completed, 12);
    }
}

#[test]
fn prop_submit_batched_equals_submit_equals_reference() {
    // The three serving paths must agree bit-exactly for ragged shapes
    // (M, N, K deliberately not multiples of the tile): batched
    // stacking exercises `Mat::block` edge zero-padding in the strip
    // slicing, per-request submit exercises affinity reuse, and the
    // widened i32 matmul is the oracle.
    let mut g = Gen(0xBA7C4);
    for round in 0..8 {
        let tile = [4usize, 8][g.range(0, 1) as usize];
        let arch = if g.next() % 2 == 0 { Arch::Dip } else { Arch::Ws };
        let cfg = CoordinatorConfig {
            devices: g.range(1, 4) as usize,
            device: DeviceConfig { arch, tile, mac_stages: 2, ..Default::default() },
            queue_depth: g.range(2, 16) as usize,
            work_stealing: g.next() % 2 == 0,
            placement: if g.next() % 2 == 0 {
                PlacementPolicy::HeatAware
            } else {
                PlacementPolicy::HashMod
            },
        };
        let nd = g.range(1, 40) as usize;
        let k = g.range(1, 40) as usize;
        let w = random_i8(nd, k, g.next());
        let batch = g.range(1, 6) as usize;
        let xs: Vec<Mat<i8>> = (0..batch)
            .map(|_| random_i8(g.range(1, 30) as usize, nd, g.next()))
            .collect();

        let c = Coordinator::new(cfg);
        let batched: Vec<Mat<i32>> = c
            .submit_batched(xs.clone(), w.clone())
            .into_iter()
            .map(|h| h.wait().out)
            .collect();
        c.shutdown();

        let c = Coordinator::new(cfg);
        let handles: Vec<_> = xs.iter().map(|x| c.submit(x.clone(), w.clone())).collect();
        let single: Vec<Mat<i32>> = handles.into_iter().map(|h| h.wait().out).collect();
        c.shutdown();

        for (i, ((x, b), s)) in xs.iter().zip(&batched).zip(&single).enumerate() {
            let want = x.widen().matmul(&w.widen());
            assert_eq!(*b, want, "batched round {round} req {i} nd={nd} k={k} tile={tile} arch={arch:?}");
            assert_eq!(*s, want, "single round {round} req {i} nd={nd} k={k} tile={tile} arch={arch:?}");
        }
    }
}

#[test]
fn prop_psum_accumulation_order_independent() {
    // The same workload through 1 device (deterministic job order) and
    // many devices (racy order) must agree bit-exactly.
    let mut g = Gen(0xACC);
    for _ in 0..5 {
        let nd = 24usize;
        let k = 16usize;
        let x = random_i8(20, nd, g.next());
        let w = random_i8(nd, k, g.next());
        let run = |devices: usize| {
            let coord = Coordinator::new(CoordinatorConfig {
                devices,
                device: DeviceConfig { arch: Arch::Dip, tile: 8, mac_stages: 2, ..Default::default() },
                queue_depth: 4,
                ..Default::default()
            });
            let out = coord.submit(x.clone(), w.clone()).wait().out;
            coord.shutdown();
            out
        };
        assert_eq!(run(1), run(5));
    }
}

#[test]
fn prop_sharded_queue_loses_and_duplicates_nothing_under_interleaving() {
    // Randomized concurrent push/pop/steal/close interleavings across
    // shards, tenants, capacities, and stealing on/off: every pushed
    // job is popped exactly once, the queue drains fully, and nothing
    // hangs. One consumer per shard (as in the coordinator, where
    // workers == devices) plus extras sharing shards.
    use std::collections::HashSet;
    use std::sync::Arc;

    let mut g = Gen(0x97E55);
    for trial in 0..12 {
        let shards = g.range(1, 5) as usize;
        let capacity = g.range(1, 8) as usize;
        let steal = g.next() % 2 == 0;
        let producers = g.range(1, 4) as usize;
        let per_producer = g.range(20, 120) as usize;
        let consumers = shards + g.range(0, 3) as usize;
        let q = Arc::new(ShardedQueue::<u64>::new(shards, capacity, steal));

        let producer_handles: Vec<_> = (0..producers)
            .map(|p| {
                let q = Arc::clone(&q);
                let mut pg = Gen(0x1000 + trial * 31 + p as u64);
                std::thread::spawn(move || {
                    for j in 0..per_producer {
                        let item = (p * 1_000_000 + j) as u64;
                        let shard = pg.range(0, shards as u64 - 1) as usize;
                        let tenant = pg.range(0, 3);
                        q.push(shard, tenant, item).unwrap();
                    }
                })
            })
            .collect();
        let consumer_handles: Vec<_> = (0..consumers)
            .map(|c| {
                let q = Arc::clone(&q);
                let mut cg = Gen(0x2000 + trial * 17 + c as u64);
                let me = c % shards;
                std::thread::spawn(move || {
                    let mut mine = Vec::new();
                    loop {
                        // Shifting "resident tile" preference exercises
                        // the out-of-order path and the skip bound.
                        let residue = cg.range(0, 6);
                        match q.pop(me, |v| v % 7 == residue) {
                            Some(p) => mine.push(p.into_inner()),
                            None => break,
                        }
                    }
                    mine
                })
            })
            .collect();
        for h in producer_handles {
            h.join().unwrap();
        }
        q.close();
        let mut all = Vec::new();
        for h in consumer_handles {
            all.extend(h.join().unwrap());
        }
        let total = producers * per_producer;
        assert_eq!(all.len(), total, "trial {trial}: lost or extra jobs");
        let unique: HashSet<u64> = all.iter().copied().collect();
        assert_eq!(unique.len(), total, "trial {trial}: duplicated jobs");
    }
}

#[test]
fn prop_front_skip_bound_holds_with_stealing_enabled() {
    // The MAX_FRONT_SKIPS anti-starvation bound is a per-lane property
    // of the owning shard and must survive stealing being enabled (a
    // second, empty-shard worker configuration steals nothing here but
    // compiles the same code path the coordinator runs).
    let q = ShardedQueue::<u32>::new(2, MAX_FRONT_SKIPS as usize + 16, true);
    q.push(0, 0, 1).unwrap(); // never preferred
    for _ in 0..MAX_FRONT_SKIPS + 8 {
        q.push(0, 0, 2).unwrap(); // always preferred
    }
    q.close();
    let mut popped_front_at = None;
    let mut i = 0u32;
    while let Some(p) = q.pop(0, |v| *v == 2) {
        if p.into_inner() == 1 {
            popped_front_at = Some(i);
        }
        i += 1;
    }
    assert_eq!(popped_front_at, Some(MAX_FRONT_SKIPS));
    // The other worker sees a drained queue, not a hang.
    assert!(q.pop(1, |_| false).is_none());
}

#[test]
fn prop_strip_submission_equals_submit_equals_reference() {
    // The serving fan-out (pre-built M1 row-block strips, row-offset
    // jobs) must agree with the batched column-strip fan-out and the
    // i32 oracle for ragged shapes across device counts and archs.
    let mut g = Gen(0x57A1B5);
    for round in 0..8 {
        let tile = [4usize, 8][g.range(0, 1) as usize];
        let arch = if g.next() % 2 == 0 { Arch::Dip } else { Arch::Ws };
        let cfg = CoordinatorConfig {
            devices: g.range(1, 4) as usize,
            device: DeviceConfig { arch, tile, mac_stages: 2, ..Default::default() },
            queue_depth: g.range(2, 16) as usize,
            work_stealing: g.next() % 2 == 0,
            placement: PlacementPolicy::HeatAware,
        };
        let m = g.range(1, 30) as usize;
        let nd = g.range(1, 30) as usize;
        let k = g.range(1, 30) as usize;
        let x = random_i8(m, nd, g.next());
        let w = random_i8(nd, k, g.next());
        let strips: Vec<Arc<Mat<i8>>> = (0..m.div_ceil(tile))
            .map(|m1| Arc::new(x.block(m1 * tile, 0, tile, nd)))
            .collect();
        let c = Coordinator::new(cfg);
        let via_strips = c.submit_strips_as(7, strips, m, &w).wait().out;
        let via_submit = c.submit(x.clone(), w.clone()).wait().out;
        c.shutdown();
        let want = x.widen().matmul(&w.widen());
        assert_eq!(via_strips, want, "round {round} m={m} nd={nd} k={k} tile={tile} {arch:?}");
        assert_eq!(via_submit, want, "round {round}");
    }
}

#[test]
fn prop_decode_trace_bit_exact_with_cache_on_vs_off() {
    // Randomized autoregressive traces: layer counts, dims, session
    // counts, prompt lengths and step counts vary; the cached run must
    // be bit-exact with the uncached baseline and strictly cheaper,
    // and the strip LRU must respect its bound (asserted inside
    // assert_cached_strictly_cheaper). Prompts are kept longer than
    // one tile so the strict row reduction is structural, not lucky.
    let mut g = Gen(0xDECDE);
    for trial in 0..4 {
        let tile = [4usize, 8][g.range(0, 1) as usize];
        let cfg = DecodeMix {
            tile,
            layers: g.range(1, 2) as usize,
            dims: LayerDims {
                d_model: 4 * g.range(2, 4) as usize,
                d_k: 4 * g.range(1, 2) as usize,
                d_ffn: 4 * g.range(2, 5) as usize,
            },
            sessions: g.range(1, 2) as usize,
            prefill_rows: tile + g.range(1, 6) as usize,
            shared_prefix_rows: g.range(0, tile as u64) as usize,
            steps: g.range(2, 3) as usize,
            devices: g.range(1, 3) as usize,
            seed: g.next(),
            strip_cache_capacity: g.range(4, 64) as usize,
        };
        let cached = run_decode_mix(&cfg, true);
        let uncached = run_decode_mix(&cfg, false);
        let ab = assert_cached_strictly_cheaper(&cached, &uncached);
        assert!(
            ab.rows_ratio > 1.0,
            "trial {trial}: tile={tile} prefill={} steps={}",
            cfg.prefill_rows,
            cfg.steps
        );
    }
}

#[test]
fn prop_wave_decode_bit_exact_with_strictly_fewer_weight_loads() {
    // Randomized continuous-batching traces: session counts, prompt
    // lengths, step counts, mid-flight joins, wave budgets, layer
    // counts, dims and device counts all vary. The wave scheduler must
    // reproduce per-session decode bit-exactly (acts and all K/V/Y
    // state, asserted inside assert_waved_strictly_cheaper) while
    // performing strictly fewer weight-tile installs, streaming
    // strictly fewer rows, and costing strictly fewer cycles. The
    // strictness is structural, not lucky: at least two sessions are
    // present from wave 0 and the row budget (>= 16) always fits one
    // decode cohort, so a multi-session wave exists; and each layer
    // has at least 6 distinct stage tiles against at most 2 devices,
    // so per-session passes must re-install tiles a wave touches once.
    let mut g = Gen(0x3A7E5);
    for trial in 0..4 {
        let sessions = g.range(2, 4) as usize;
        let specs: Vec<WaveSessionSpec> = (0..sessions)
            .map(|i| WaveSessionSpec {
                join_after: if i < 2 { 0 } else { g.range(0, 3) as usize },
                prompt_rows: 4 + g.range(0, 8) as usize,
                steps: g.range(1, 3) as usize,
            })
            .collect();
        let cfg = WaveMix {
            tile: 8,
            layers: g.range(1, 2) as usize,
            dims: LayerDims {
                d_model: 8 * g.range(1, 2) as usize,
                d_k: 8,
                d_ffn: 8 * g.range(1, 3) as usize,
            },
            sessions: specs,
            devices: g.range(1, 2) as usize,
            seed: g.next(),
            strip_cache_capacity: g.range(8, 64) as usize,
            policy: WavePolicy {
                max_wave_rows: 16 + g.range(0, 48) as usize,
                max_sessions: g.range(2, 8) as usize,
                ..Default::default()
            },
        };
        let waved = run_wave_mix(&cfg);
        let solo = run_wave_mix_per_session(&cfg);
        let ab = assert_waved_strictly_cheaper(&waved, &solo);
        assert!(
            ab.weight_loads_ratio > 1.0 && ab.rows_ratio > 1.0,
            "trial {trial}: sessions={} devices={} budget={}",
            cfg.sessions.len(),
            cfg.devices,
            cfg.policy.max_wave_rows
        );
        // Sessions joining, leaving, and splitting over the budget must
        // never stack a multi-session wave past the row budget.
        for r in &waved.reports {
            assert!(
                r.sessions == 1 || r.stacked_rows <= cfg.policy.max_wave_rows,
                "trial {trial}: wave {} overfilled ({} rows)",
                r.wave,
                r.stacked_rows
            );
        }
    }
}

#[test]
fn prop_seeded_fault_schedules_keep_wave_mixes_exact_and_balanced() {
    // Chaos over randomized wave mixes: a seeded fault schedule
    // (quarantine-length failure burst, scattered transients, a
    // straggler, and one permanent device death, all from
    // `FaultPlan::from_seed`) replayed against the real fleet must
    // leave every observable output bit-exact against the fault-free
    // run of the same mix, lose and duplicate no jobs (each job's
    // successful execution is charged exactly once, so `jobs_executed`
    // matches the clean run exactly), and settle a balanced retry
    // ledger. `shutdown` re-audits every run's full double-entry
    // ledger on top of the assertions here.
    use dip_core::fault::FaultPlan;

    let mut g = Gen(0xFA017);
    for trial in 0..3 {
        let sessions = g.range(2, 4) as usize;
        let specs: Vec<WaveSessionSpec> = (0..sessions)
            .map(|i| WaveSessionSpec {
                join_after: if i < 2 { 0 } else { g.range(0, 2) as usize },
                prompt_rows: 4 + g.range(0, 8) as usize,
                steps: g.range(1, 3) as usize,
            })
            .collect();
        let cfg = WaveMix {
            tile: 8,
            layers: g.range(1, 2) as usize,
            dims: LayerDims {
                d_model: 8 * g.range(1, 2) as usize,
                d_k: 8,
                d_ffn: 8 * g.range(1, 3) as usize,
            },
            sessions: specs,
            devices: g.range(2, 4) as usize,
            seed: g.next(),
            strip_cache_capacity: g.range(8, 64) as usize,
            policy: WavePolicy {
                max_wave_rows: 16 + g.range(0, 48) as usize,
                max_sessions: g.range(2, 8) as usize,
                ..Default::default()
            },
        };
        let fault_seed = g.next();
        let ctx = format!(
            "trial {trial}: sessions={} devices={} fault_seed={fault_seed}",
            cfg.sessions.len(),
            cfg.devices
        );
        let clean = run_wave_mix(&cfg);
        let plan = FaultPlan::from_seed(fault_seed, cfg.devices);
        let chaotic = run_wave_mix_with_faults(&cfg, plan);

        // Bit-exact degradation: faults may slow the run, never change it.
        assert_eq!(chaotic.acts, clean.acts, "{ctx}: generated token rows diverged");
        assert_eq!(chaotic.layers, clean.layers, "{ctx}: per-layer K/V/Y state diverged");

        // No job loss, no duplication: every job's successful execution
        // is charged exactly once, failed attempts move nothing.
        let (c, q) = (&clean.metrics, &chaotic.metrics);
        // (`sim_cycles` is *not* compared: re-homing after the death
        // legitimately changes install/skip patterns, so cycle totals
        // may differ even though every output is bit-exact.)
        assert_eq!(q.jobs_executed, c.jobs_executed, "{ctx}: lost or duplicated jobs");
        assert_eq!(q.requests_completed, c.requests_completed, "{ctx}: lost requests");

        // The retry ledger balances, and retry immunity means no
        // request is ever abandoned.
        assert_eq!(q.jobs_failed, q.jobs_retried + q.jobs_abandoned, "{ctx}");
        assert_eq!(q.jobs_abandoned, 0, "{ctx}: immune retries must always succeed");
        assert!(q.jobs_retried <= q.faults_injected, "{ctx}");
        assert!(q.quarantines_exited <= q.quarantines_entered, "{ctx}");
        // At most one victim dies per seeded plan (whether its death
        // slot is reached depends on how placement shares the mix).
        assert!(q.device_deaths <= 1, "{ctx}: seeded plans schedule one death");
        if q.jobs_failed == 0 {
            assert_eq!(q.failed_cycles, 0, "{ctx}: failed cycles need a failed job");
        }
    }
}

#[test]
fn prop_wave_mix_trace_is_well_formed_and_conserves() {
    // The flight recorder's contract over randomized wave mixes: the
    // settled trace must be well-formed — spans nest, causal ids
    // resolve against the control track, per-device cycle stamps are
    // monotone — and its event tallies must partition exactly into the
    // settled ledger (check::audit::audit_trace), with zero ring drops
    // and one queue-wait histogram sample per executed job.
    let mut g = Gen(0x0B5EC);
    for trial in 0..4 {
        let sessions = g.range(2, 4) as usize;
        let specs: Vec<WaveSessionSpec> = (0..sessions)
            .map(|i| WaveSessionSpec {
                join_after: if i < 2 { 0 } else { g.range(0, 3) as usize },
                prompt_rows: 4 + g.range(0, 8) as usize,
                steps: g.range(1, 3) as usize,
            })
            .collect();
        let cfg = WaveMix {
            tile: 8,
            layers: g.range(1, 2) as usize,
            dims: LayerDims {
                d_model: 8 * g.range(1, 2) as usize,
                d_k: 8,
                d_ffn: 8 * g.range(1, 3) as usize,
            },
            sessions: specs,
            devices: g.range(1, 3) as usize,
            seed: g.next(),
            strip_cache_capacity: g.range(8, 64) as usize,
            policy: WavePolicy {
                max_wave_rows: 16 + g.range(0, 48) as usize,
                max_sessions: g.range(2, 8) as usize,
                ..Default::default()
            },
        };
        let o = run_wave_mix(&cfg);
        let violations = o.trace.validate();
        assert!(
            violations.is_empty(),
            "trial {trial}: malformed trace:\n{}",
            violations.join("\n")
        );
        let counts = o.trace.counts();
        let report = audit_trace(&counts, &o.metrics);
        assert!(report.is_balanced(), "trial {trial}: trace-ledger audit failed:\n{report}");
        assert_eq!(counts.dropped, 0, "trial {trial}: rings must never drop");
        assert_eq!(
            o.trace.merged_wait_hist().count(),
            o.metrics.jobs_executed,
            "trial {trial}: one wait sample per executed job"
        );
        // Device tracks partition the executed jobs.
        let track_jobs: u64 = o.trace.devices.iter().map(|d| d.jobs).sum();
        assert_eq!(track_jobs, o.metrics.jobs_executed, "trial {trial}");
    }
}

#[test]
fn prop_critical_path_attribution_conserves_and_bounds_hold() {
    // The profiler's contract over randomized wave mixes: causal
    // attribution must partition the device-cycle budget exactly —
    // every category on every device sums back to the makespan, and
    // the totals double-enter against the settled ledger
    // (check::audit::audit_critpath) — and every what-if
    // counterfactual must be a true lower bound: removing work can
    // never predict a makespan above the measured one.
    let mut g = Gen(0xC417);
    for trial in 0..4 {
        let sessions = g.range(2, 4) as usize;
        let specs: Vec<WaveSessionSpec> = (0..sessions)
            .map(|i| WaveSessionSpec {
                join_after: if i < 2 { 0 } else { g.range(0, 3) as usize },
                prompt_rows: 4 + g.range(0, 8) as usize,
                steps: g.range(1, 3) as usize,
            })
            .collect();
        let cfg = WaveMix {
            tile: 8,
            layers: g.range(1, 2) as usize,
            dims: LayerDims {
                d_model: 8 * g.range(1, 2) as usize,
                d_k: 8,
                d_ffn: 8 * g.range(1, 3) as usize,
            },
            sessions: specs,
            devices: g.range(1, 3) as usize,
            seed: g.next(),
            strip_cache_capacity: g.range(8, 64) as usize,
            policy: WavePolicy {
                max_wave_rows: 16 + g.range(0, 48) as usize,
                max_sessions: g.range(2, 8) as usize,
                ..Default::default()
            },
        };
        let o = run_wave_mix(&cfg);
        let attr = attribute(&o.trace);
        assert!(
            attr.conserves(),
            "trial {trial}: categories must partition the cycle budget:\n{}",
            attr.render()
        );
        let report = audit_critpath(&attr, &o.metrics);
        assert!(report.is_balanced(), "trial {trial}: critpath audit failed:\n{report}");
        let bounds = what_if(&attr);
        for c in &bounds.counterfactuals {
            assert!(
                c.predicted_makespan <= attr.makespan,
                "trial {trial}: counterfactual {} predicts {} cycles above the measured {}",
                c.name,
                c.predicted_makespan,
                attr.makespan
            );
            assert!(
                c.speedup_bound >= 1.0,
                "trial {trial}: counterfactual {} speedup bound {} < 1",
                c.name,
                c.speedup_bound
            );
        }
    }
}

#[test]
fn prop_act_strip_lru_bound_and_pointer_sharing() {
    // Random key traffic with a small working set: the cache never
    // exceeds its capacity, and a hit always returns the identical
    // allocation inserted on the miss.
    let mut g = Gen(0xACCA);
    for trial in 0..10 {
        let shards = g.range(1, 4) as usize;
        let capacity = g.range(1, 12) as usize;
        let metrics = Arc::new(Metrics::default());
        let cache = ActStripCache::new(shards, capacity, Arc::clone(&metrics));
        for op in 0..200 {
            let seed = g.range(1, 24); // small key space forces reuse + eviction
            let strip = random_i8(4, 3, seed);
            let key = strip.content_hash();
            let got = cache.get_or_build(key, || strip.clone());
            assert_eq!(*got, strip, "trial {trial} op {op}: wrong strip content");
            assert!(
                cache.len() <= cache.capacity(),
                "trial {trial} op {op}: LRU bound exceeded ({} > {})",
                cache.len(),
                cache.capacity()
            );
            // An immediate second lookup is a hit and must be the same
            // allocation, never a copy.
            let again = cache.get_or_build(key, || strip.clone());
            assert!(Arc::ptr_eq(&got, &again), "trial {trial} op {op}: hit copied the strip");
        }
        let s = metrics.snapshot();
        assert_eq!(s.act_strip_hits + s.act_strip_misses, 400);
        assert!(s.act_strip_hits >= 200, "trial {trial}: immediate re-lookups must hit");
    }
}

#[test]
fn prop_stage_accumulators_stay_inside_the_inferred_intervals() {
    // Soundness of the analyzer's value-range pass: run random layer
    // configs *concretely* — the same stage semantics `run_layer_wave`
    // executes (widened i8 matmuls, prior-K/V concatenation, causal
    // masking at the session row offset, `narrow` between stages) —
    // and assert every intermediate i32 lies inside the interval
    // `check::analyze::ranges` infers for its stage. The abstract
    // interpreter must over-approximate every concrete execution,
    // decode shapes (accumulated prefix rows, nonzero row0) included.
    use dip_core::check::analyze::ranges::{max_safe_seq_len, stage_interval};
    use dip_core::serving::{layer_graph, narrow_mat, StageId};

    let nodes = layer_graph();
    let node = |id: StageId| *nodes.iter().find(|n| n.id == id).expect("stage present");
    let mut g = Gen(0x50A9D);
    for case in 0..40 {
        let dims = LayerDims {
            d_model: g.range(1, 48) as usize,
            d_k: g.range(1, 32) as usize,
            d_ffn: g.range(1, 64) as usize,
        };
        let prior = g.range(0, 24) as usize; // session rows already accumulated
        let rows = g.range(1, 16) as usize; // new rows this pass
        let seq = prior + rows;
        let seed = g.next();
        let ctx = format!(
            "case {case} dims={}/{}/{} prior={prior} rows={rows} seed={seed}",
            dims.d_model, dims.d_k, dims.d_ffn
        );

        let check = |id: StageId, m: &Mat<i32>| {
            let iv = stage_interval(&node(id), &dims, seq);
            assert!(iv.fits_i32(), "{ctx}: {id:?} interval must fit i32 at seq={seq}");
            for r in 0..m.rows() {
                for c in 0..m.cols() {
                    assert!(
                        iv.contains(m.get(r, c) as i64),
                        "{ctx}: {id:?}[{r},{c}] = {} escapes the inferred [{}, {}]",
                        m.get(r, c),
                        iv.lo,
                        iv.hi
                    );
                }
            }
        };

        let x = random_i8(rows, dims.d_model, seed);
        let wq = random_i8(dims.d_model, dims.d_k, seed + 1);
        let wk = random_i8(dims.d_model, dims.d_k, seed + 2);
        let wv = random_i8(dims.d_model, dims.d_k, seed + 3);
        let wo = random_i8(dims.d_k, dims.d_model, seed + 4);
        let w1 = random_i8(dims.d_model, dims.d_ffn, seed + 5);
        let w2 = random_i8(dims.d_ffn, dims.d_model, seed + 6);

        let q_acc = x.widen().matmul(&wq.widen());
        check(StageId::Q, &q_acc);
        let q = narrow_mat(&q_acc);
        let k_acc = x.widen().matmul(&wk.widen());
        check(StageId::K, &k_acc);
        let k = narrow_mat(&k_acc);
        let v_acc = x.widen().matmul(&wv.widen());
        check(StageId::V, &v_acc);
        let v = narrow_mat(&v_acc);

        // Session-accumulated attention operands: prior rows (already
        // narrowed at earlier steps, so full-range i8) ahead of this
        // pass's rows — exactly `with_prior` in the executor.
        let k_full = if prior == 0 { k } else { random_i8(prior, dims.d_k, seed + 7).vconcat(&k) };
        let v_full = if prior == 0 { v } else { random_i8(prior, dims.d_k, seed + 8).vconcat(&v) };

        let mut s_acc = q.widen().matmul(&k_full.transpose().widen());
        for r in 0..rows {
            for j in (prior + r + 1)..seq {
                s_acc.set(r, j, 0); // mask_causal at row0 = prior
            }
        }
        check(StageId::Scores, &s_acc);
        let s = narrow_mat(&s_acc);

        let c_acc = s.widen().matmul(&v_full.widen()); // contracts over seq
        check(StageId::Context, &c_acc);
        let c = narrow_mat(&c_acc);

        let o_acc = c.widen().matmul(&wo.widen());
        check(StageId::OutProj, &o_acc);
        let o = narrow_mat(&o_acc);
        let u_acc = o.widen().matmul(&w1.widen());
        check(StageId::FfnUp, &u_acc);
        let u = narrow_mat(&u_acc);
        check(StageId::FfnDown, &u.widen().matmul(&w2.widen()));

        // The derived bound sits exactly on the interval analysis'
        // frontier: every stage fits at the bound, some stage fails one
        // step past it. (These dims are all far below the i8×i8 depth
        // cap, so Context binds and the bound is the full 131071.)
        let msl = max_safe_seq_len(&dims);
        assert_eq!(msl, 131_071, "{ctx}");
        assert!(
            nodes.iter().all(|n| stage_interval(n, &dims, msl).fits_i32()),
            "{ctx}: every stage must fit at the proven bound"
        );
        assert!(
            nodes.iter().any(|n| !stage_interval(n, &dims, msl + 1).fits_i32()),
            "{ctx}: one step past the bound must overflow"
        );
    }
}

#[test]
fn prop_event_counts_scale_linearly_with_rows() {
    // MAC events must be exactly rows * N^2; active-PE cycles likewise.
    let mut g = Gen(0xE7);
    for _ in 0..20 {
        let n = g.range(2, 16) as usize;
        let rows = g.range(1, 50) as usize;
        let w = random_i8(n, n, g.next());
        let x = random_i8(rows, n, g.next());
        let mut dip = DipArray::new(n, 2);
        dip.load_weights(&w);
        let st = dip.run_tile(&x).stats;
        assert_eq!(st.events.mac_ops, (rows * n * n) as u64);
        assert_eq!(st.events.pe_active_cycles, (rows * n * n) as u64);
        assert_eq!(st.total_ops, 2 * (rows * n * n) as u64);
    }
}
