//! End-to-end tests over the PJRT runtime: the AOT HLO artifacts
//! (JAX + Pallas compile path) executed from Rust, cross-checked against
//! the cycle-accurate simulators and the reference artifacts.
//!
//! These tests are skipped (with a notice) when `make artifacts` has not
//! been run — CI runs them after the artifact build.
//!
//! The whole target is gated behind the non-default `pjrt` cargo
//! feature (`required-features` in Cargo.toml): the `xla` bindings are
//! not in the offline vendored crate set, so the default tier-1
//! `cargo test` never tries to compile this file.

use dip_core::coordinator::{Coordinator, CoordinatorConfig, DeviceConfig};
use dip_core::matrix::Mat;
use dip_core::runtime::{random_f32, Runtime};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime_or_skip() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return None;
    }
    Some(Runtime::new(artifacts_dir()).expect("runtime"))
}

#[test]
fn all_artifacts_compile_and_execute() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let names: Vec<String> = rt.manifest().names().iter().map(|s| s.to_string()).collect();
    assert!(names.len() >= 10, "{names:?}");
    for name in names {
        let shapes = rt.manifest().entry(&name).unwrap().inputs.clone();
        let inputs: Vec<Vec<f32>> = shapes
            .iter()
            .map(|s| random_f32(s.iter().product(), 1, 0.1))
            .collect();
        let out = rt.run_f32(&name, &inputs).unwrap();
        assert!(!out.is_empty(), "{name}");
        assert!(out.iter().all(|v| v.is_finite()), "{name} produced non-finite values");
    }
}

#[test]
fn dip_pairs_match_references_through_xla() {
    let Some(mut rt) = runtime_or_skip() else { return };
    for (dip, ref_, tol) in [
        ("matmul_dip_256", "matmul_ref_256", 1e-3),
        ("mha_dip", "mha_ref", 5e-3),
        ("ffn_dip", "ffn_ref", 5e-3),
        ("layer_dip", "layer_ref", 5e-3),
    ] {
        for seed in [1u64, 2, 3] {
            let (_, _, max) = rt.verify_pair(dip, ref_, seed).unwrap();
            assert!(max < tol, "{dip} seed {seed}: max diff {max}");
        }
    }
}

#[test]
fn pjrt_tile_matmul_matches_cycle_accurate_simulator() {
    // The same INT8 tile through (a) the Pallas dataflow artifact via
    // PJRT and (b) the Rust cycle-accurate DiP array must agree: the
    // two implementations of the paper's dataflow are equivalent.
    let Some(mut rt) = runtime_or_skip() else { return };
    use dip_core::arch::permute::permute;
    use dip_core::arch::SystolicArray;
    use dip_core::matrix::random_i8;

    let xi = random_i8(64, 64, 10);
    let wi = random_i8(64, 64, 11);

    let mut sim = dip_core::arch::dip::DipArray::new(64, 2);
    sim.load_weights(&wi);
    let sim_out = sim.run_tile(&xi).outputs;

    let x: Vec<f32> = xi.as_slice().iter().map(|&v| v as f32).collect();
    let wp = permute(&wi);
    let wpf: Vec<f32> = wp.as_slice().iter().map(|&v| v as f32).collect();
    let got = rt.run_f32("dip_tile_matmul", &[x, wpf]).unwrap();

    for (i, (g, s)) in got.iter().zip(sim_out.as_slice()).enumerate() {
        assert!(
            (g - *s as f32).abs() < 0.5,
            "element {i}: pjrt {g} vs sim {s}"
        );
    }
}

#[test]
fn coordinator_serving_cross_checked_against_pjrt() {
    // Serve a request through the coordinator (simulated arrays) and
    // compare against the PJRT reference matmul artifact.
    let Some(mut rt) = runtime_or_skip() else { return };
    use dip_core::analytical::Arch;
    use dip_core::matrix::random_i8;

    let xi = random_i8(64, 64, 20);
    let wi = random_i8(64, 64, 21);

    let coord = Coordinator::new(CoordinatorConfig {
        devices: 2,
        device: DeviceConfig { arch: Arch::Dip, tile: 64, mac_stages: 2, ..Default::default() },
        queue_depth: 8,
        ..Default::default()
    });
    let served: Mat<i32> = coord.submit(xi.clone(), wi.clone()).wait().out;
    coord.shutdown();

    let x: Vec<f32> = xi.as_slice().iter().map(|&v| v as f32).collect();
    let w: Vec<f32> = wi.as_slice().iter().map(|&v| v as f32).collect();
    let pjrt = rt.run_f32("matmul_ref_64", &[x, w]).unwrap();

    for (i, (p, s)) in pjrt.iter().zip(served.as_slice()).enumerate() {
        assert!((p - *s as f32).abs() < 0.5, "element {i}: pjrt {p} vs served {s}");
    }
}
