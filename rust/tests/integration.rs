//! Cross-module integration tests: the full metrics pipeline (sims →
//! tiling → power model → harness), the coordinator serving path, and
//! consistency between every layer of the reproduction.

use dip_core::analytical::{compare::compare_at, Arch};
use dip_core::arch::{dip::DipArray, ws::WsArray, SystolicArray};
use dip_core::bench_harness::scenarios::{
    assert_cached_strictly_cheaper, assert_waved_strictly_cheaper, cold_share_with_growing_plug,
    run_decode_mix, run_wave_mix, run_wave_mix_per_session, serve_two_model_bursts, DecodeMix,
    FloodScenario, TwoModelBurst, WaveMix, WaveSessionSpec,
};
use dip_core::bench_harness::{fig5, fig6, table1, table2, table4};
use dip_core::coordinator::{Coordinator, CoordinatorConfig, DeviceConfig, PlacementPolicy};
use dip_core::matrix::{random_i8, Mat};
use dip_core::power::energy;
use dip_core::serving::{LayerDims, WavePolicy};
use dip_core::tiling::schedule::{compare_workload, workload_cost, TilingConfig};
use dip_core::workloads::dims::{layer_workloads, MatMulDims};
use dip_core::workloads::models::model_by_name;

#[test]
fn fig5_harness_is_internally_consistent() {
    for row in fig5::run(2) {
        let a = row.analytical;
        // Sim == closed form, both latency and TFPU.
        assert_eq!(row.ws_sim_latency, a.ws_latency);
        assert_eq!(row.dip_sim_latency, a.dip_latency);
        assert_eq!(row.ws_sim_tfpu, a.ws_tfpu);
        assert_eq!(row.dip_sim_tfpu, a.dip_tfpu);
        // Cross-check against compare_at.
        let again = compare_at(a.n, a.s);
        assert_eq!(again.ws_latency, a.ws_latency);
    }
}

#[test]
fn paper_headline_claims_hold_end_to_end() {
    // "throughput improvement up to 50%"
    let r64 = compare_at(64, 2);
    assert!(r64.throughput_improvement_pct > 45.0 && r64.throughput_improvement_pct < 50.0);
    // "TFPU by up to 50%"
    assert!(r64.tfpu_improvement_pct > 49.0);
    // "energy efficiency per area up to 2.02x"
    let overall_max = table2::run().iter().map(|r| r.overall_x).fold(0.0, f64::max);
    assert!(overall_max > 1.9 && overall_max < 2.1, "{overall_max}");
    // "area savings up to 8.12%, power savings up to 19.95%"
    let t1 = table1::run();
    assert!(t1.iter().any(|r| r.saved_area_pct > 7.0));
    assert!(t1.iter().any(|r| r.saved_power_pct > 16.0));
    // "8.2 TOPS with energy efficiency 9.55 TOPS/W"
    assert!((energy::peak_tops(64) - 8.192).abs() < 0.01);
    assert!((energy::tops_per_watt(Arch::Dip, 64) - 9.55).abs() < 0.5);
    // Table IV rows
    let accs = table4::accelerators();
    assert!(accs[0].normalized().tops_per_w > 3.0 * accs[1].normalized().tops_per_w);
}

#[test]
fn fig6_band_endpoints_match_paper() {
    // Representative small + large workloads (full sweep in the bench).
    let small = compare_workload(MatMulDims::new(64, 64, 64));
    assert!((small.latency_improvement() - 1.49).abs() < 0.02);
    assert!((small.energy_improvement() - 1.81).abs() < 0.06, "{}", small.energy_improvement());
    let large = compare_workload(MatMulDims::new(2048, 5120, 5120));
    assert!((large.latency_improvement() - 1.03).abs() < 0.015);
    assert!((large.energy_improvement() - 1.25).abs() < 0.04, "{}", large.energy_improvement());
}

#[test]
fn bert_layer_wins_on_both_axes() {
    let bert = model_by_name("BERT").unwrap();
    let mut ws_total = 0u64;
    let mut dip_total = 0u64;
    for w in bert.layer_workloads(128) {
        ws_total += workload_cost(w.dims, &TilingConfig::ws64()).cycles * w.repeats;
        dip_total += workload_cost(w.dims, &TilingConfig::dip64()).cycles * w.repeats;
    }
    let ratio = ws_total as f64 / dip_total as f64;
    assert!(ratio > 1.1 && ratio < 1.5, "BERT layer latency ratio {ratio}");
}

#[test]
fn table_iii_workload_dims_cover_all_stages() {
    let ws = layer_workloads(128, 768, 12, 64, 3072);
    let dims: Vec<MatMulDims> = ws.iter().map(|w| w.dims).collect();
    assert!(dims.contains(&MatMulDims::new(128, 768, 64))); // QKV
    assert!(dims.contains(&MatMulDims::new(128, 64, 128))); // scores
    assert!(dims.contains(&MatMulDims::new(128, 128, 64))); // attn out
    assert!(dims.contains(&MatMulDims::new(128, 768, 768))); // out proj
    assert!(dims.contains(&MatMulDims::new(128, 768, 3072))); // FFN W1
    assert!(dims.contains(&MatMulDims::new(128, 3072, 768))); // FFN W2
}

#[test]
fn coordinator_and_tiling_agree_numerically() {
    // The threaded serving path and the single-threaded tiling path
    // must produce identical outputs for identical requests.
    let x = random_i8(40, 48, 1);
    let w = random_i8(48, 24, 2);
    let cfg = TilingConfig { tile: 8, arch: Arch::Dip, mac_stages: 2, weight_load: Default::default() };
    let (tiled, _) = dip_core::tiling::schedule::run_tiled_matmul(&x, &w, &cfg);

    let coord = Coordinator::new(CoordinatorConfig {
        devices: 3,
        device: DeviceConfig { arch: Arch::Dip, tile: 8, mac_stages: 2, ..Default::default() },
        queue_depth: 16,
        ..Default::default()
    });
    let served = coord.submit(x.clone(), w.clone()).wait().out;
    coord.shutdown();

    assert_eq!(tiled, served);
    assert_eq!(tiled, x.widen().matmul(&w.widen()));
}

#[test]
fn serving_reuses_stationary_weights_across_requests() {
    // The ROADMAP serving scenario: one model layer, many requests. A
    // single-tile weight pins every job to one affinity device, so the
    // scheduler must install the tile exactly once and skip the load on
    // every later request — while staying bit-exact — on both archs.
    for arch in [Arch::Dip, Arch::Ws] {
        let coord = Coordinator::new(CoordinatorConfig {
            devices: 2,
            device: DeviceConfig { arch, tile: 8, mac_stages: 2, ..Default::default() },
            queue_depth: 16,
            work_stealing: false, // strict affinity: reuse is deterministic
            ..Default::default()
        });
        let w = random_i8(8, 8, 77);
        for i in 0..6 {
            let x = random_i8(10, 8, 200 + i);
            assert_eq!(
                coord.submit(x.clone(), w.clone()).wait().out,
                x.widen().matmul(&w.widen())
            );
        }
        let m = coord.shutdown();
        assert_eq!(m.jobs_executed, 6);
        assert_eq!(m.weight_loads, 1, "{arch:?}");
        assert_eq!(m.weight_loads_skipped, 5, "{arch:?}");
        let per_load = match arch {
            Arch::Dip => 7, // N-1: the last row overlaps the first input
            Arch::Ws => 8,  // N
        };
        assert_eq!(m.weight_load_cycles_saved, 5 * per_load, "{arch:?}");
    }
}

#[test]
fn heat_aware_placement_beats_hash_on_two_model_serving() {
    // The ROADMAP "smarter placement than hash % devices" scenario: with
    // these models the PR 1 modulus co-locates 5 of the 8 layer pairs
    // (and stacks half the work on one device); power-of-two-choices
    // spreads them. Both serve bit-exact outputs (asserted inside the
    // shared scenario); heat-aware must win strictly on reuse *and*
    // balance. Deterministic: sequential submit+wait, stealing off.
    let cfg = TwoModelBurst { tile: 8, seed_a: 8100, seed_b: 8150, burst: 3 };
    let hash = serve_two_model_bursts(&cfg, PlacementPolicy::HashMod);
    let heat = serve_two_model_bursts(&cfg, PlacementPolicy::HeatAware);
    let total = (2 * 8 * cfg.burst) as u64;
    assert_eq!(hash.metrics.jobs_executed, total);
    assert_eq!(heat.metrics.jobs_executed, total);
    assert_eq!(hash.device_jobs.iter().sum::<u64>(), total);
    assert_eq!(heat.device_jobs.iter().sum::<u64>(), total);

    assert!(
        heat.metrics.weight_reuse_rate() > hash.metrics.weight_reuse_rate(),
        "heat-aware reuse {:.2} must beat hash reuse {:.2}",
        heat.metrics.weight_reuse_rate(),
        hash.metrics.weight_reuse_rate()
    );
    // Deterministic margins (validated against an exact model of the
    // placement + residency state machine): hash ~25%, heat ~67%.
    assert!(hash.metrics.weight_reuse_rate() < 0.45, "{}", hash.metrics.weight_reuse_rate());
    assert!(heat.metrics.weight_reuse_rate() > 0.55, "{}", heat.metrics.weight_reuse_rate());

    assert!(
        heat.job_spread() < hash.job_spread(),
        "heat skew {:?} must be tighter than hash skew {:?}",
        heat.device_jobs,
        hash.device_jobs
    );
}

#[test]
fn placement_map_is_strictly_affine_across_requests() {
    // Repeated traffic never re-homes tiles absent imbalance: every
    // distinct tile is placed exactly once however often it recurs.
    let coord = Coordinator::new(CoordinatorConfig {
        devices: 4,
        device: DeviceConfig { arch: Arch::Dip, tile: 8, mac_stages: 2, ..Default::default() },
        queue_depth: 16,
        work_stealing: false,
        placement: PlacementPolicy::HeatAware,
    });
    let weights: Vec<Mat<i8>> = (0..6).map(|i| random_i8(8, 8, 500 + i)).collect();
    for round in 0..5 {
        for w in &weights {
            let x = random_i8(8, 8, 700 + round);
            coord.submit(x, w.clone()).wait();
        }
    }
    let p = coord.placement_snapshot();
    assert_eq!(p.placements, 6);
    assert_eq!(p.tiles, 6);
    assert_eq!(p.rebalances, 0, "balanced traffic must not re-home tiles");
    assert_eq!(p.device_tiles.iter().sum::<usize>(), 6);
    assert_eq!(p.device_heat.iter().sum::<u64>(), 6 * 5);
    coord.shutdown();
}

#[test]
fn cold_tenant_keeps_its_share_while_hot_tenant_floods() {
    // With the backlog held by the plug, DRR lanes alternate service,
    // so the cold tenant's share at its completion is ~50% and the 25%
    // fairness floor holds with a wide margin. The shared scenario
    // gates on the contention precondition and grows the plug 4x when
    // a slow machine let the backlog drain early (every response is
    // verified bit-exact inside it).
    let cfg = FloodScenario { tile: 8, hot_requests: 160, cold_requests: 40, plug_rows: 1 << 15 };
    let Some(out) = cold_share_with_growing_plug(cfg, 4) else {
        // The backlog never held: this machine drained faster than it
        // submitted on every attempt, so the end-to-end share says
        // nothing. Exactness was still verified on every attempt, and
        // the DRR fairness guarantee itself is covered deterministically
        // by the queue-level tests — skip rather than fail on timing.
        eprintln!("fairness share inconclusive on this machine (backlog never held); skipping");
        return;
    };
    let share = out.cold_share.unwrap();
    assert!(
        share >= 0.25,
        "cold tenant got {share:.2} of served jobs under flood (hot {} at cold completion)",
        out.hot_served_at_cold_done
    );
}

#[test]
fn serving_activation_cache_ab_bit_exact_and_strictly_cheaper() {
    // The serving acceptance scenario: a three-session decode mix
    // (shared prompt prefix, per-session tails, prefill + 5 steps)
    // served with activation caching on vs off. Caching must strictly
    // reduce streamed rows and simulated cycles while every generated
    // row and all per-layer K/V/output state stay bit-exact — causality
    // makes per-row stage outputs step-invariant, so reuse is lossless.
    let cfg = DecodeMix {
        tile: 8,
        layers: 2,
        dims: LayerDims { d_model: 16, d_k: 8, d_ffn: 24 },
        sessions: 3,
        prefill_rows: 12,
        shared_prefix_rows: 8,
        steps: 5,
        devices: 2,
        seed: 4200,
        strip_cache_capacity: 256,
    };
    let cached = run_decode_mix(&cfg, true);
    let uncached = run_decode_mix(&cfg, false);
    let ab = assert_cached_strictly_cheaper(&cached, &uncached);
    assert!(ab.cycles_ratio > 1.0 && ab.rows_ratio > 1.0);
    assert!(ab.strip_hit_rate > 0.0 && ab.bytes_saved > 0);
    // Cached decode steps stream exactly the one fed-back row.
    for r in cached.per_step.iter().skip(cfg.sessions) {
        assert_eq!(r.rows_processed, 1, "session {} streamed a prefix it should reuse", r.session);
        assert!(r.rows_reused > 0);
    }
    // The strip cache pays off already at prefill: K/V re-slice the
    // same input Q streamed, and later sessions share the prompt
    // prefix blocks of earlier ones.
    let prefill_hits: u64 = cached.per_step.iter().take(cfg.sessions).map(|r| r.strip_hits).sum();
    assert!(prefill_hits > 0, "prefill must hit the strip cache");
}

#[test]
fn wave_batched_decode_with_joins_and_leaves_is_bit_exact_and_cheaper() {
    // The continuous-batching acceptance scenario: five sessions with
    // staggered lengths, three present from the start, two joining
    // mid-flight (waves 2 and 4), all leaving at different waves. The
    // wave scheduler must reproduce per-session decode bit-exactly
    // (acts and all K/V/Y layer state) while performing strictly fewer
    // weight-tile installs, streaming strictly fewer rows, and costing
    // strictly fewer simulated cycles — each stage weight is loaded
    // once per wave instead of once per session.
    let cfg = WaveMix {
        tile: 8,
        layers: 2,
        dims: LayerDims { d_model: 16, d_k: 8, d_ffn: 24 },
        sessions: vec![
            WaveSessionSpec { join_after: 0, prompt_rows: 12, steps: 6 },
            WaveSessionSpec { join_after: 0, prompt_rows: 9, steps: 4 },
            WaveSessionSpec { join_after: 0, prompt_rows: 11, steps: 8 },
            WaveSessionSpec { join_after: 2, prompt_rows: 10, steps: 5 },
            WaveSessionSpec { join_after: 4, prompt_rows: 8, steps: 3 },
        ],
        devices: 2,
        seed: 7300,
        strip_cache_capacity: 512,
        policy: WavePolicy { max_wave_rows: 48, max_sessions: 8, ..Default::default() },
    };
    let waved = run_wave_mix(&cfg);
    let solo = run_wave_mix_per_session(&cfg);
    let ab = assert_waved_strictly_cheaper(&waved, &solo);
    assert!(ab.weight_loads_ratio > 1.0 && ab.cycles_ratio > 1.0 && ab.rows_ratio > 1.0);

    // The join/leave trace is deterministic: joins land exactly where
    // the specs say, the cohort never exceeds the policy, and every
    // session leaves exactly once.
    let joins: usize = waved.reports.iter().map(|r| r.joined).sum();
    assert_eq!(joins, cfg.sessions.len());
    assert_eq!(waved.reports[0].joined, 3, "three sessions present from the start");
    assert_eq!(waved.reports[2].joined, 1, "session 3 joins at wave 2");
    assert_eq!(waved.reports[4].joined, 1, "session 4 joins at wave 4");
    let left: Vec<u64> =
        waved.reports.iter().flat_map(|r| r.completed.iter().copied()).collect();
    let mut sorted = left.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, vec![0, 1, 2, 3, 4], "every session leaves exactly once");
    for r in &waved.reports {
        assert!(r.sessions >= 1 && r.sessions <= cfg.policy.max_sessions);
        assert!(
            r.sessions == 1 || r.stacked_rows <= cfg.policy.max_wave_rows,
            "wave {} stacked {} rows over budget with a multi-session cohort",
            r.wave,
            r.stacked_rows
        );
    }
    // Session 1 (prefill + 4 steps, present from wave 0) leaves at
    // wave 5; the longest session (id 2, 9 passes) bounds the trace.
    assert_eq!(waved.reports.len(), 9);
    assert!(waved.reports[4].completed.contains(&1));
    assert_eq!(waved.reports[8].completed, vec![2]);
    // Mid-flight joins ride a shared wave: wave 2 stacks session 3's
    // 10-row prefill with the three 1-row decode streams.
    assert_eq!(waved.reports[2].sessions, 4);
    assert_eq!(waved.reports[2].stacked_rows, 13);
}

#[test]
fn warm_steals_prefer_resident_tiles_and_skip_the_reload() {
    // Deterministic, single-threaded steal: the thief device already
    // holds tile W stationary; the victim's lane queues a W job first
    // and an unrelated job last. A cold steal would take the lane tail
    // (the unrelated job) and pay a reload — placement-aware stealing
    // must take the W job instead, and executing it must skip the load.
    use dip_core::coordinator::{
        Device, Job, Metrics, Pop, ReqState, ShardedQueue, SubRequest, DEFAULT_TENANT,
    };
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use std::time::Instant;

    fn job_for(x: &Mat<i8>, w: &Mat<i8>) -> Job {
        let (tx, _rx) = channel();
        let req = Arc::new(ReqState::new(
            x.rows(),
            w.cols(),
            w.cols(),
            1,
            vec![SubRequest { id: 0, row0: 0, rows: x.rows(), tx }],
        ));
        let w_tile = Arc::new(w.clone());
        let tile_id = w_tile.content_hash();
        Job {
            req,
            w_tile,
            x_strip: Arc::new(x.clone()),
            r0: 0,
            c0: 0,
            tile_id,
            tenant: DEFAULT_TENANT,
            enqueued_at: Instant::now(),
            attempt: 0,
        }
    }

    let metrics = Arc::new(Metrics::default());
    let dcfg = DeviceConfig { arch: Arch::Dip, tile: 8, mac_stages: 2, ..Default::default() };
    let mut thief = Device::new(dcfg, 1, Arc::clone(&metrics));
    let x = random_i8(8, 8, 1);
    let w_warm = random_i8(8, 8, 2);
    let w_cold = random_i8(8, 8, 3);

    // Make W resident on the thief.
    thief.execute(job_for(&x, &w_warm));
    assert_eq!(metrics.snapshot().weight_loads, 1);

    // Victim shard 0 queues [warm job, cold job]; the thief is worker 1.
    let q: ShardedQueue<Job> = ShardedQueue::new(2, 8, true);
    q.push(0, DEFAULT_TENANT, job_for(&x, &w_warm)).unwrap();
    q.push(0, DEFAULT_TENANT, job_for(&x, &w_cold)).unwrap();
    q.close();

    let resident = thief.loaded_tile_id();
    let popped = q.pop(1, |j: &Job| Some(j.tile_id) == resident || thief.has_prepared(j.tile_id));
    let Some(Pop::Stolen(job)) = popped else {
        panic!("thief must steal from the victim's backlog")
    };
    assert_eq!(job.tile_id, w_warm.content_hash(), "steal must pick the warm job, not the tail");
    thief.execute(job);
    let m = metrics.snapshot();
    assert_eq!(m.weight_loads, 1, "warm steal must not reload");
    assert_eq!(m.weight_loads_skipped, 1, "warm steal skips the stationary install");

    // The cold job is the shard's last: reserved for its owner.
    assert!(q.pop(1, |_: &Job| false).is_none());
    assert!(matches!(q.pop(0, |_: &Job| false), Some(Pop::Local(_))));
}

#[test]
fn coordinator_flood_counts_warm_steals() {
    // End-to-end: a single-tile weight flood pins every job's affinity
    // to one device; with stealing on, helpers that steal repeatedly
    // end up warm (the tile becomes resident on them after their first
    // steal). Thread timing decides how many steals happen, so the
    // assertion is conditional on stealing having occurred at all —
    // the invariant steals_warm <= steals always holds.
    let coord = Coordinator::new(CoordinatorConfig {
        devices: 2,
        device: DeviceConfig { arch: Arch::Dip, tile: 8, mac_stages: 2, ..Default::default() },
        queue_depth: 128,
        work_stealing: true,
        ..Default::default()
    });
    let w = random_i8(8, 8, 60);
    let handles: Vec<_> = (0..64)
        .map(|i| {
            let x = random_i8(64, 8, 700 + i);
            (x.clone(), coord.submit(x, w.clone()))
        })
        .collect();
    for (x, h) in handles {
        assert_eq!(h.wait().out, x.widen().matmul(&w.widen()));
    }
    let m = coord.shutdown();
    assert!(m.steals_warm <= m.steals);
    if m.steals > 1 {
        // After its first steal the helper holds the tile resident, so
        // every later steal of this single-tile flood is warm.
        assert!(m.steals_warm >= m.steals - 1, "steals {} warm {}", m.steals, m.steals_warm);
    }
}

#[test]
fn ws_and_dip_disagree_only_on_time_never_on_values() {
    let x = random_i8(30, 16, 5);
    let w = random_i8(16, 16, 6);
    let mut dip = DipArray::new(16, 2);
    let mut ws = WsArray::new(16, 2);
    dip.load_weights(&w);
    ws.load_weights(&w);
    let d = dip.run_tile(&x);
    let s = ws.run_tile(&x);
    assert_eq!(d.outputs, s.outputs);
    assert!(d.stats.cycles < s.stats.cycles);
    assert_eq!(d.stats.events.mac_ops, s.stats.events.mac_ops);
    assert_eq!(d.stats.events.fifo8_writes, 0);
    assert!(s.stats.events.fifo8_writes > 0);
}

#[test]
fn energy_model_consistency_across_paths() {
    // workload_cost's paper energy == power_mw * cycles for both archs.
    for (arch, cfg) in [(Arch::Ws, TilingConfig::ws64()), (Arch::Dip, TilingConfig::dip64())] {
        let c = workload_cost(MatMulDims::new(128, 128, 128), &cfg);
        let expect_uj = energy::power_mw(arch, 64) * c.cycles as f64 / 1e6;
        assert!((c.energy_uj - expect_uj).abs() / expect_uj < 1e-9);
        // Event-based is always <= paper accounting for WS (partially
        // occupied FIFOs), and close to it for DiP.
        if arch == Arch::Ws {
            assert!(c.energy_event_uj < c.energy_uj);
        }
    }
}

#[test]
fn fig6_json_export_shape() {
    let points = fig6::run(64);
    let json = fig6::to_json(&points).render();
    let parsed = dip_core::jsonio::Json::parse(&json).unwrap();
    let arr = parsed.as_arr().unwrap();
    assert_eq!(arr.len(), points.len());
    for item in arr {
        assert!(item.get("energy_improvement").unwrap().as_f64().unwrap() > 1.0);
        assert!(item.get("latency_improvement").unwrap().as_f64().unwrap() > 1.0);
    }
}
