//! Cross-module integration tests: the full metrics pipeline (sims →
//! tiling → power model → harness), the coordinator serving path, and
//! consistency between every layer of the reproduction.

use dip_core::analytical::{compare::compare_at, Arch};
use dip_core::arch::{dip::DipArray, ws::WsArray, SystolicArray};
use dip_core::bench_harness::scenarios::{
    cold_share_with_growing_plug, serve_two_model_bursts, FloodScenario, TwoModelBurst,
};
use dip_core::bench_harness::{fig5, fig6, table1, table2, table4};
use dip_core::coordinator::{Coordinator, CoordinatorConfig, DeviceConfig, PlacementPolicy};
use dip_core::matrix::{random_i8, Mat};
use dip_core::power::energy;
use dip_core::tiling::schedule::{compare_workload, workload_cost, TilingConfig};
use dip_core::workloads::dims::{layer_workloads, MatMulDims};
use dip_core::workloads::models::model_by_name;

#[test]
fn fig5_harness_is_internally_consistent() {
    for row in fig5::run(2) {
        let a = row.analytical;
        // Sim == closed form, both latency and TFPU.
        assert_eq!(row.ws_sim_latency, a.ws_latency);
        assert_eq!(row.dip_sim_latency, a.dip_latency);
        assert_eq!(row.ws_sim_tfpu, a.ws_tfpu);
        assert_eq!(row.dip_sim_tfpu, a.dip_tfpu);
        // Cross-check against compare_at.
        let again = compare_at(a.n, a.s);
        assert_eq!(again.ws_latency, a.ws_latency);
    }
}

#[test]
fn paper_headline_claims_hold_end_to_end() {
    // "throughput improvement up to 50%"
    let r64 = compare_at(64, 2);
    assert!(r64.throughput_improvement_pct > 45.0 && r64.throughput_improvement_pct < 50.0);
    // "TFPU by up to 50%"
    assert!(r64.tfpu_improvement_pct > 49.0);
    // "energy efficiency per area up to 2.02x"
    let overall_max = table2::run().iter().map(|r| r.overall_x).fold(0.0, f64::max);
    assert!(overall_max > 1.9 && overall_max < 2.1, "{overall_max}");
    // "area savings up to 8.12%, power savings up to 19.95%"
    let t1 = table1::run();
    assert!(t1.iter().any(|r| r.saved_area_pct > 7.0));
    assert!(t1.iter().any(|r| r.saved_power_pct > 16.0));
    // "8.2 TOPS with energy efficiency 9.55 TOPS/W"
    assert!((energy::peak_tops(64) - 8.192).abs() < 0.01);
    assert!((energy::tops_per_watt(Arch::Dip, 64) - 9.55).abs() < 0.5);
    // Table IV rows
    let accs = table4::accelerators();
    assert!(accs[0].normalized().tops_per_w > 3.0 * accs[1].normalized().tops_per_w);
}

#[test]
fn fig6_band_endpoints_match_paper() {
    // Representative small + large workloads (full sweep in the bench).
    let small = compare_workload(MatMulDims::new(64, 64, 64));
    assert!((small.latency_improvement() - 1.49).abs() < 0.02);
    assert!((small.energy_improvement() - 1.81).abs() < 0.06, "{}", small.energy_improvement());
    let large = compare_workload(MatMulDims::new(2048, 5120, 5120));
    assert!((large.latency_improvement() - 1.03).abs() < 0.015);
    assert!((large.energy_improvement() - 1.25).abs() < 0.04, "{}", large.energy_improvement());
}

#[test]
fn bert_layer_wins_on_both_axes() {
    let bert = model_by_name("BERT").unwrap();
    let mut ws_total = 0u64;
    let mut dip_total = 0u64;
    for w in bert.layer_workloads(128) {
        ws_total += workload_cost(w.dims, &TilingConfig::ws64()).cycles * w.repeats;
        dip_total += workload_cost(w.dims, &TilingConfig::dip64()).cycles * w.repeats;
    }
    let ratio = ws_total as f64 / dip_total as f64;
    assert!(ratio > 1.1 && ratio < 1.5, "BERT layer latency ratio {ratio}");
}

#[test]
fn table_iii_workload_dims_cover_all_stages() {
    let ws = layer_workloads(128, 768, 12, 64, 3072);
    let dims: Vec<MatMulDims> = ws.iter().map(|w| w.dims).collect();
    assert!(dims.contains(&MatMulDims::new(128, 768, 64))); // QKV
    assert!(dims.contains(&MatMulDims::new(128, 64, 128))); // scores
    assert!(dims.contains(&MatMulDims::new(128, 128, 64))); // attn out
    assert!(dims.contains(&MatMulDims::new(128, 768, 768))); // out proj
    assert!(dims.contains(&MatMulDims::new(128, 768, 3072))); // FFN W1
    assert!(dims.contains(&MatMulDims::new(128, 3072, 768))); // FFN W2
}

#[test]
fn coordinator_and_tiling_agree_numerically() {
    // The threaded serving path and the single-threaded tiling path
    // must produce identical outputs for identical requests.
    let x = random_i8(40, 48, 1);
    let w = random_i8(48, 24, 2);
    let cfg = TilingConfig { tile: 8, arch: Arch::Dip, mac_stages: 2, weight_load: Default::default() };
    let (tiled, _) = dip_core::tiling::schedule::run_tiled_matmul(&x, &w, &cfg);

    let coord = Coordinator::new(CoordinatorConfig {
        devices: 3,
        device: DeviceConfig { arch: Arch::Dip, tile: 8, mac_stages: 2, ..Default::default() },
        queue_depth: 16,
        ..Default::default()
    });
    let served = coord.submit(x.clone(), w.clone()).wait().out;
    coord.shutdown();

    assert_eq!(tiled, served);
    assert_eq!(tiled, x.widen().matmul(&w.widen()));
}

#[test]
fn serving_reuses_stationary_weights_across_requests() {
    // The ROADMAP serving scenario: one model layer, many requests. A
    // single-tile weight pins every job to one affinity device, so the
    // scheduler must install the tile exactly once and skip the load on
    // every later request — while staying bit-exact — on both archs.
    for arch in [Arch::Dip, Arch::Ws] {
        let coord = Coordinator::new(CoordinatorConfig {
            devices: 2,
            device: DeviceConfig { arch, tile: 8, mac_stages: 2, ..Default::default() },
            queue_depth: 16,
            work_stealing: false, // strict affinity: reuse is deterministic
            ..Default::default()
        });
        let w = random_i8(8, 8, 77);
        for i in 0..6 {
            let x = random_i8(10, 8, 200 + i);
            assert_eq!(
                coord.submit(x.clone(), w.clone()).wait().out,
                x.widen().matmul(&w.widen())
            );
        }
        let m = coord.shutdown();
        assert_eq!(m.jobs_executed, 6);
        assert_eq!(m.weight_loads, 1, "{arch:?}");
        assert_eq!(m.weight_loads_skipped, 5, "{arch:?}");
        let per_load = match arch {
            Arch::Dip => 7, // N-1: the last row overlaps the first input
            Arch::Ws => 8,  // N
        };
        assert_eq!(m.weight_load_cycles_saved, 5 * per_load, "{arch:?}");
    }
}

#[test]
fn heat_aware_placement_beats_hash_on_two_model_serving() {
    // The ROADMAP "smarter placement than hash % devices" scenario: with
    // these models the PR 1 modulus co-locates 5 of the 8 layer pairs
    // (and stacks half the work on one device); power-of-two-choices
    // spreads them. Both serve bit-exact outputs (asserted inside the
    // shared scenario); heat-aware must win strictly on reuse *and*
    // balance. Deterministic: sequential submit+wait, stealing off.
    let cfg = TwoModelBurst { tile: 8, seed_a: 8100, seed_b: 8150, burst: 3 };
    let hash = serve_two_model_bursts(&cfg, PlacementPolicy::HashMod);
    let heat = serve_two_model_bursts(&cfg, PlacementPolicy::HeatAware);
    let total = (2 * 8 * cfg.burst) as u64;
    assert_eq!(hash.metrics.jobs_executed, total);
    assert_eq!(heat.metrics.jobs_executed, total);
    assert_eq!(hash.device_jobs.iter().sum::<u64>(), total);
    assert_eq!(heat.device_jobs.iter().sum::<u64>(), total);

    assert!(
        heat.metrics.weight_reuse_rate() > hash.metrics.weight_reuse_rate(),
        "heat-aware reuse {:.2} must beat hash reuse {:.2}",
        heat.metrics.weight_reuse_rate(),
        hash.metrics.weight_reuse_rate()
    );
    // Deterministic margins (validated against an exact model of the
    // placement + residency state machine): hash ~25%, heat ~67%.
    assert!(hash.metrics.weight_reuse_rate() < 0.45, "{}", hash.metrics.weight_reuse_rate());
    assert!(heat.metrics.weight_reuse_rate() > 0.55, "{}", heat.metrics.weight_reuse_rate());

    assert!(
        heat.job_spread() < hash.job_spread(),
        "heat skew {:?} must be tighter than hash skew {:?}",
        heat.device_jobs,
        hash.device_jobs
    );
}

#[test]
fn placement_map_is_strictly_affine_across_requests() {
    // Repeated traffic never re-homes tiles absent imbalance: every
    // distinct tile is placed exactly once however often it recurs.
    let coord = Coordinator::new(CoordinatorConfig {
        devices: 4,
        device: DeviceConfig { arch: Arch::Dip, tile: 8, mac_stages: 2, ..Default::default() },
        queue_depth: 16,
        work_stealing: false,
        placement: PlacementPolicy::HeatAware,
    });
    let weights: Vec<Mat<i8>> = (0..6).map(|i| random_i8(8, 8, 500 + i)).collect();
    for round in 0..5 {
        for w in &weights {
            let x = random_i8(8, 8, 700 + round);
            coord.submit(x, w.clone()).wait();
        }
    }
    let p = coord.placement_snapshot();
    assert_eq!(p.placements, 6);
    assert_eq!(p.tiles, 6);
    assert_eq!(p.rebalances, 0, "balanced traffic must not re-home tiles");
    assert_eq!(p.device_tiles.iter().sum::<usize>(), 6);
    assert_eq!(p.device_heat.iter().sum::<u64>(), 6 * 5);
    coord.shutdown();
}

#[test]
fn cold_tenant_keeps_its_share_while_hot_tenant_floods() {
    // With the backlog held by the plug, DRR lanes alternate service,
    // so the cold tenant's share at its completion is ~50% and the 25%
    // fairness floor holds with a wide margin. The shared scenario
    // gates on the contention precondition and grows the plug 4x when
    // a slow machine let the backlog drain early (every response is
    // verified bit-exact inside it).
    let cfg = FloodScenario { tile: 8, hot_requests: 160, cold_requests: 40, plug_rows: 1 << 15 };
    let Some(out) = cold_share_with_growing_plug(cfg, 4) else {
        // The backlog never held: this machine drained faster than it
        // submitted on every attempt, so the end-to-end share says
        // nothing. Exactness was still verified on every attempt, and
        // the DRR fairness guarantee itself is covered deterministically
        // by the queue-level tests — skip rather than fail on timing.
        eprintln!("fairness share inconclusive on this machine (backlog never held); skipping");
        return;
    };
    let share = out.cold_share.unwrap();
    assert!(
        share >= 0.25,
        "cold tenant got {share:.2} of served jobs under flood (hot {} at cold completion)",
        out.hot_served_at_cold_done
    );
}

#[test]
fn ws_and_dip_disagree_only_on_time_never_on_values() {
    let x = random_i8(30, 16, 5);
    let w = random_i8(16, 16, 6);
    let mut dip = DipArray::new(16, 2);
    let mut ws = WsArray::new(16, 2);
    dip.load_weights(&w);
    ws.load_weights(&w);
    let d = dip.run_tile(&x);
    let s = ws.run_tile(&x);
    assert_eq!(d.outputs, s.outputs);
    assert!(d.stats.cycles < s.stats.cycles);
    assert_eq!(d.stats.events.mac_ops, s.stats.events.mac_ops);
    assert_eq!(d.stats.events.fifo8_writes, 0);
    assert!(s.stats.events.fifo8_writes > 0);
}

#[test]
fn energy_model_consistency_across_paths() {
    // workload_cost's paper energy == power_mw * cycles for both archs.
    for (arch, cfg) in [(Arch::Ws, TilingConfig::ws64()), (Arch::Dip, TilingConfig::dip64())] {
        let c = workload_cost(MatMulDims::new(128, 128, 128), &cfg);
        let expect_uj = energy::power_mw(arch, 64) * c.cycles as f64 / 1e6;
        assert!((c.energy_uj - expect_uj).abs() / expect_uj < 1e-9);
        // Event-based is always <= paper accounting for WS (partially
        // occupied FIFOs), and close to it for DiP.
        if arch == Arch::Ws {
            assert!(c.energy_event_uj < c.energy_uj);
        }
    }
}

#[test]
fn fig6_json_export_shape() {
    let points = fig6::run(64);
    let json = fig6::to_json(&points).render();
    let parsed = dip_core::jsonio::Json::parse(&json).unwrap();
    let arr = parsed.as_arr().unwrap();
    assert_eq!(arr.len(), points.len());
    for item in arr {
        assert!(item.get("energy_improvement").unwrap().as_f64().unwrap() > 1.0);
        assert!(item.get("latency_improvement").unwrap().as_f64().unwrap() > 1.0);
    }
}
