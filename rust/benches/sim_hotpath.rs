//! Bench: the cycle-accurate simulator hot loop — the performance-
//! critical path of every table/figure regeneration. Reports PE-updates
//! per second (DESIGN.md §Perf target: >= 1e8/s).
//! `cargo bench --bench sim_hotpath`.

use dip_core::arch::{dip::DipArray, ws::WsArray, SystolicArray};
use dip_core::matrix::random_i8;
use dip_core::bench_harness::timing::{bench, report_throughput};

fn pe_updates(n: usize, rows: usize, extra_cycles: usize) -> f64 {
    // Every cycle updates all N*N PEs; total cycles ~ rows + fill/drain.
    ((rows + extra_cycles) * n * n) as f64
}

fn main() {
    println!("=== Simulator hot path (PE-updates/s) ===");

    for (n, rows) in [(16usize, 256usize), (64, 64), (64, 1024), (64, 4096)] {
        let w = random_i8(n, n, 1);
        let x = random_i8(rows, n, 2);

        let mut dip = DipArray::new(n, 2);
        dip.load_weights(&w);
        let r = bench(&format!("dip/n{n}/rows{rows}"), 1, 7, || dip.run_tile(&x));
        report_throughput("PE-updates", r.throughput(pe_updates(n, rows, n)), "/s");

        let mut ws = WsArray::new(n, 2);
        ws.load_weights(&w);
        let r = bench(&format!("ws/n{n}/rows{rows}"), 1, 7, || ws.run_tile(&x));
        report_throughput("PE-updates", r.throughput(pe_updates(n, rows, 2 * n)), "/s");
    }

    // Weight load + permutation staging cost.
    let w = random_i8(64, 64, 3);
    let mut dip = DipArray::new(64, 2);
    let r = bench("dip/load_weights_64 (incl. permutation)", 5, 50, || dip.load_weights(&w));
    report_throughput("loads", r.throughput(1.0), "/s");
}
