//! Bench: the cycle-accurate simulator hot loop — the performance-
//! critical path of every table/figure regeneration and every serving
//! decode step. Reports PE-updates per second (DESIGN.md §Perf target:
//! >= 1e8/s) for the derotated-GEMM kernel path (`run_tile`) against
//! the pre-kernel wavefront implementation (`run_tile_legacy`), per
//! (arch, n, rows) config, plus the weight prepare+load staging cost.
//! `cargo bench --bench sim_hotpath`.
//!
//! Emits `BENCH_sim.json` (machine-readable trajectory: PE-updates/s
//! kernel vs legacy and the speedup per config) so the sim-path perf
//! trajectory is tracked like `BENCH_serving.json` /
//! `BENCH_coordinator.json`.
//!
//! Invariants asserted on every run (and relied on by the CI smoke,
//! `DIP_BENCH_SMOKE=1`): the kernel path is bit-identical to the
//! legacy path in outputs *and* stats for every config, and at the
//! n=64 streaming configs it is no slower (the PR target is >= 4x at
//! n=64/rows=1024; the recorded `speedup` tracks it).

use dip_core::arch::{dip::DipArray, ws::WsArray, SystolicArray, TileRun};
use dip_core::bench_harness::report::Json;
use dip_core::bench_harness::timing::{bench, report_throughput, smoke_mode};
use dip_core::matrix::random_i8;

/// PE register updates a run performs: every cycle touches all N*N PEs
/// (active or gated); total cycles ~ rows + fill/drain overhead.
fn pe_updates(n: usize, rows: usize, extra_cycles: usize) -> f64 {
    ((rows + extra_cycles) * n * n) as f64
}

struct ConfigResult {
    arch: &'static str,
    n: usize,
    rows: usize,
    /// Median-based PE-updates/s — the honest numbers the JSON records.
    kernel_per_s: f64,
    legacy_per_s: f64,
    /// Best-sample (min wall time) PE-updates/s — what the regression
    /// gate compares, so one descheduled sample on a loaded CI runner
    /// cannot fail the smoke.
    kernel_best_per_s: f64,
    legacy_best_per_s: f64,
}

impl ConfigResult {
    fn speedup(&self) -> f64 {
        self.kernel_per_s / self.legacy_per_s
    }

    fn best_speedup(&self) -> f64 {
        self.kernel_best_per_s / self.legacy_best_per_s
    }
}

/// Bench one (arch, n, rows) config both ways, asserting bit-exact
/// equivalence between the kernel and legacy paths first.
fn run_config<A: SystolicArray>(
    arch: &mut A,
    legacy: impl Fn(&mut A, &dip_core::matrix::Mat<i8>) -> TileRun,
    n: usize,
    rows: usize,
    extra_cycles: usize,
    iters: u32,
) -> ConfigResult {
    let w = random_i8(n, n, 1);
    let x = random_i8(rows, n, 2);
    arch.load_weights(&w);

    // Equivalence gate: the A/B below must measure two implementations
    // of the *same* function.
    let name = arch.name();
    let fast = arch.run_tile(&x);
    let slow = legacy(&mut *arch, &x);
    assert_eq!(fast.outputs, slow.outputs, "{name}/n{n}/rows{rows}: outputs diverged");
    assert_eq!(fast.stats, slow.stats, "{name}/n{n}/rows{rows}: stats diverged");

    let updates = pe_updates(n, rows, extra_cycles);
    let rk = bench(&format!("{name}/n{n}/rows{rows}/kernel"), 1, iters, || arch.run_tile(&x));
    report_throughput("PE-updates", rk.throughput(updates), "/s");
    let rl =
        bench(&format!("{name}/n{n}/rows{rows}/legacy"), 1, iters, || legacy(&mut *arch, &x));
    report_throughput("PE-updates", rl.throughput(updates), "/s");
    let out = ConfigResult {
        arch: name,
        n,
        rows,
        kernel_per_s: rk.throughput(updates),
        legacy_per_s: rl.throughput(updates),
        kernel_best_per_s: updates / rk.min.as_secs_f64(),
        legacy_best_per_s: updates / rl.min.as_secs_f64(),
    };
    println!("  -> kernel vs legacy: {:.2}x", out.speedup());
    out
}

fn main() {
    let smoke = smoke_mode();
    if smoke {
        println!("[smoke mode: reduced iterations]");
    }
    let iters = if smoke { 3 } else { 7 };
    println!("=== Simulator hot path (PE-updates/s, kernel vs legacy) ===");

    let mut results: Vec<ConfigResult> = Vec::new();
    for (n, rows) in [(16usize, 256usize), (64, 64), (64, 1024), (64, 4096)] {
        let mut dip = DipArray::new(n, 2);
        results.push(run_config(&mut dip, |a, x| a.run_tile_legacy(x), n, rows, n, iters));
        let mut ws = WsArray::new(n, 2);
        results.push(run_config(&mut ws, |a, x| a.run_tile_legacy(x), n, rows, 2 * n, iters));
    }

    // Weight staging cost: prepare (permutation + widening + derotated
    // layout) + install, the host-side work the device LRU amortizes.
    let w = random_i8(64, 64, 3);
    let mut dip = DipArray::new(64, 2);
    let r = bench("DiP/load_weights_64 (incl. permutation)", 5, 50, || dip.load_weights(&w));
    report_throughput("loads", r.throughput(1.0), "/s");
    let loads_per_s = r.throughput(1.0);

    // Smoke invariants: at the wide streaming configs the kernel path
    // must not be slower than the legacy wavefront it replaced (the
    // margin target is >= 4x at n=64/rows=1024; the JSON records the
    // real median ratio). The gate compares best samples — a loaded CI
    // runner descheduling one timing sample must not fail the step —
    // and smoke mode keeps a small extra tolerance on top.
    let floor = if smoke { 0.9 } else { 1.0 };
    for r in results.iter().filter(|r| r.n == 64 && r.rows >= 1024) {
        assert!(
            r.best_speedup() >= floor,
            "{}/n{}/rows{}: kernel path regressed below the legacy wavefront \
             (best-sample {:.2}x, median {:.2}x, floor {floor})",
            r.arch,
            r.n,
            r.rows,
            r.best_speedup(),
            r.speedup()
        );
    }
    let headline = results
        .iter()
        .find(|r| r.arch == "DiP" && r.n == 64 && r.rows == 1024)
        .expect("headline config present");
    println!(
        "\nheadline: DiP n=64 rows=1024 kernel {:.3e} PE-updates/s ({:.2}x over legacy, target >= 4x; >= 1e8/s goal {})",
        headline.kernel_per_s,
        headline.speedup(),
        if headline.kernel_per_s >= 1e8 { "met" } else { "NOT met" },
    );

    // Machine-readable trajectory for future PRs.
    let json = Json::obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("pe_updates_target_per_s", Json::num(1e8)),
        (
            "configs",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("arch", Json::str(r.arch)),
                            ("n", Json::num(r.n as f64)),
                            ("rows", Json::num(r.rows as f64)),
                            ("pe_updates_per_s_kernel", Json::num(r.kernel_per_s)),
                            ("pe_updates_per_s_legacy", Json::num(r.legacy_per_s)),
                            ("speedup", Json::num(r.speedup())),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("headline_kernel_pe_updates_per_s", Json::num(headline.kernel_per_s)),
        ("headline_speedup", Json::num(headline.speedup())),
        ("load_weights_64_per_s", Json::num(loads_per_s)),
    ]);
    std::fs::write("BENCH_sim.json", json.render()).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json");
}
