//! Bench: the beyond-paper extension studies DESIGN.md calls out —
//! (1) sparsity zero-gating (paper §V future work), (2) the §II
//! dataflow bandwidth comparison, (3) the Meissa (§I) comparator.
//! `cargo bench --bench extensions`.

use dip_core::analytical::{latency_cycles, meissa, Arch};
use dip_core::arch::sparsity::{random_sparse_i8, run_tile_zero_gated};
use dip_core::bench_harness::timing::bench;
use dip_core::matrix::random_i8;
use dip_core::power::area::area_um2;
use dip_core::power::bandwidth::{bandwidth, Dataflow};

fn main() {
    // ------------------------------------------------------------------
    // 1. Sparsity sweep (paper §V: "explore sparsity in transformers").
    // ------------------------------------------------------------------
    println!("=== Sparsity zero-gating sweep (64x64 DiP, 512-row stream) ===");
    println!("{:>9} {:>10} {:>12} {:>14}", "density", "gated MACs", "energy x", "output check");
    let w = random_i8(64, 64, 1);
    for density in [1.0, 0.9, 0.7, 0.5, 0.3, 0.1] {
        let x = random_sparse_i8(512, 64, density, 2);
        let s = run_tile_zero_gated(Arch::Dip, &w, &x, 2);
        let ok = s.run.outputs == x.widen().matmul(&w.widen());
        println!(
            "{:>9.2} {:>10} {:>12.3} {:>14}",
            s.density,
            s.gated_macs,
            s.energy_improvement(),
            if ok { "exact" } else { "MISMATCH" }
        );
        assert!(ok);
    }
    let x = random_sparse_i8(512, 64, 0.5, 3);
    bench("sparsity/gated_pass_64x512", 1, 7, || run_tile_zero_gated(Arch::Dip, &w, &x, 2));

    // ------------------------------------------------------------------
    // 2. §II dataflow bandwidth comparison, quantified.
    // ------------------------------------------------------------------
    println!("\n=== Dataflow boundary bandwidth (N=64, R=1024 rows/pass) ===");
    println!("{:>5} {:>12} {:>12} {:>12} {:>12} {:>14}", "flow", "operand B/c", "output B/c", "refill B/c", "total B/c", "MACs/byte");
    for df in [Dataflow::Ws, Dataflow::Is, Dataflow::Os, Dataflow::Rs, Dataflow::Dip] {
        let b = bandwidth(df, 64, 1024);
        println!(
            "{:>5} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>14.1}",
            df.name(),
            b.operand_bpc,
            b.output_bpc,
            b.refill_bpc,
            b.total_bpc(),
            b.macs_per_byte(64)
        );
    }
    let ws = bandwidth(Dataflow::Ws, 64, 1024);
    let os = bandwidth(Dataflow::Os, 64, 1024);
    assert_eq!(os.operand_bpc, 2.0 * ws.operand_bpc, "OS must double operand bandwidth");

    // ------------------------------------------------------------------
    // 2b. OS dataflow cycle comparison (the §II re-pass penalty).
    // ------------------------------------------------------------------
    println!("\n=== OS vs DiP cycles (16x16 arrays, streamed rows) ===");
    {
        use dip_core::arch::{dip::DipArray, os::OsArray, SystolicArray};
        let n = 16usize;
        let w = random_i8(n, n, 11);
        println!("{:>8} {:>10} {:>10} {:>8}", "rows", "OS cyc", "DiP cyc", "ratio");
        for rows in [16usize, 64, 256] {
            let x = random_i8(rows, n, 12);
            let mut os = OsArray::new(n, 2);
            os.load_weights(&w);
            let mut dip = DipArray::new(n, 2);
            dip.load_weights(&w);
            let (or, dr) = (os.run_tile(&x), dip.run_tile(&x));
            assert_eq!(or.outputs, dr.outputs, "dataflows must agree on values");
            println!(
                "{:>8} {:>10} {:>10} {:>8.2}",
                rows,
                or.stats.cycles,
                dr.stats.cycles,
                or.stats.cycles as f64 / dr.stats.cycles as f64
            );
        }
        println!("(OS re-fills per n-row output tile; DiP streams continuously)");
    }

    // ------------------------------------------------------------------
    // 3. Meissa comparator (§I related work, quantified).
    // ------------------------------------------------------------------
    println!("\n=== Meissa vs WS vs DiP (latency cycles / area um2) ===");
    println!("{:>5} {:>10} {:>10} {:>10} {:>14} {:>14}", "N", "WS lat", "Meissa lat", "DiP lat", "Meissa area", "DiP area");
    for n in [8u64, 16, 32, 64, 128] {
        println!(
            "{:>5} {:>10} {:>10} {:>10} {:>14.0} {:>14.0}",
            n,
            latency_cycles(Arch::Ws, n, 2),
            meissa::latency_meissa(n),
            latency_cycles(Arch::Dip, n, 2),
            meissa::area_meissa_um2(n),
            area_um2(Arch::Dip, n),
        );
    }
    println!("(Meissa beats WS on latency but its adder-tree routing term makes");
    println!(" its area scale worse than DiP — the paper's §I scalability claim)");
}
