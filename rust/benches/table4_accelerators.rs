//! Bench: regenerate Table IV (accelerator comparison, 22nm-normalized)
//! and validate the headline DiP efficiency numbers.

use dip_core::bench_harness::{table4, timing::bench};

fn main() {
    println!("=== Table IV regeneration ===");
    print!("{}", table4::render());

    let dip = dip_core::power::scaling::dip_accelerator();
    let norm = dip.normalized();
    assert!((dip.peak_tops - 8.192).abs() < 0.01, "peak TOPS {}", dip.peak_tops);
    assert!((norm.tops_per_w - 9.55).abs() < 0.5, "TOPS/W {}", norm.tops_per_w);

    bench("table4/normalization", 5, 100, || {
        table4::accelerators().iter().map(|a| a.normalized().tops_per_w).sum::<f64>()
    });
}
