//! Bench: regenerate Fig. 5 (analytical comparison + simulator
//! cross-check) and time the sweep. `cargo bench --bench fig5_analytical`.

use dip_core::bench_harness::{fig5, timing::bench};

fn main() {
    println!("=== Fig 5 regeneration (paper: analytical models, eqs (1)-(7)) ===");
    let rows = fig5::run(2);
    print!("{}", fig5::render(&rows));

    bench("fig5/full_sweep_with_sim_crosscheck", 1, 5, || fig5::run(2));
    bench("fig5/analytical_only", 2, 20, || {
        dip_core::analytical::compare::fig5_sweep(2)
    });
}
