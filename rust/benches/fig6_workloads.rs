//! Bench: regenerate Fig. 6 — the cycle-accurate transformer workload
//! evaluation (energy + latency, DiP vs TPU-like 64x64) — and time the
//! sweep. `cargo bench --bench fig6_workloads`.

use dip_core::bench_harness::{fig6, timing::bench};
use dip_core::tiling::schedule::compare_workload;
use dip_core::workloads::dims::MatMulDims;

fn main() {
    println!("=== Fig 6 regeneration (transformer workloads, 64x64) ===");
    let points = fig6::run(2048);
    print!("{}", fig6::render(&points));

    let (e_min, e_max, l_min, l_max) = fig6::bands(&points);
    assert!(l_max > 1.45 && l_min < 1.06, "latency band shape broke: {l_min}..{l_max}");
    assert!(e_max > 1.7 && e_min < 1.35, "energy band shape broke: {e_min}..{e_max}");

    // Event-based accounting ablation (honest FIFO occupancy pricing).
    println!("\nEvent-based energy accounting (ablation):");
    for p in points.iter().take(4) {
        println!(
            "  {}: paper {:.2}x, event-based {:.2}x",
            p.cmp.dims,
            p.cmp.energy_improvement(),
            p.cmp.energy_improvement_event()
        );
    }

    bench("fig6/small_workload_pair", 1, 10, || {
        compare_workload(MatMulDims::new(64, 512, 64))
    });
    bench("fig6/large_workload_pair", 0, 3, || {
        compare_workload(MatMulDims::new(2048, 5120, 5120))
    });
    let r = bench("fig6/full_sweep_seq<=512", 0, 3, || fig6::run(512));
    dip_core::bench_harness::timing::report_throughput(
        "sweep wall",
        r.median.as_secs_f64(),
        "s/run",
    );
}
