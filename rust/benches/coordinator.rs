//! Bench: L3 coordinator serving throughput — requests/s, batched vs
//! unbatched, repeated-weight affinity reuse, DiP vs WS device pools.
//! `cargo bench --bench coordinator`.

use dip_core::analytical::Arch;
use dip_core::bench_harness::timing::{bench, report_throughput};
use dip_core::coordinator::{Coordinator, CoordinatorConfig, DeviceConfig, MetricsSnapshot};
use dip_core::matrix::{random_i8, Mat};

fn config(arch: Arch, devices: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        devices,
        device: DeviceConfig { arch, tile: 64, mac_stages: 2 },
        queue_depth: 256,
        work_stealing: true,
    }
}

fn serve(arch: Arch, devices: usize, requests: usize, batch: usize, verify: bool) -> MetricsSnapshot {
    let coord = Coordinator::new(config(arch, devices));
    let w = random_i8(256, 256, 7);
    let mut handles = Vec::new();
    let mut i = 0;
    while i < requests {
        let chunk = batch.min(requests - i);
        let xs: Vec<Mat<i8>> = (0..chunk).map(|j| random_i8(64, 256, (i + j) as u64)).collect();
        handles.extend(coord.submit_batched(xs, w.clone()));
        i += chunk;
    }
    if verify {
        // Acceptance check: every served output bit-exact vs Mat::matmul.
        let ww = w.widen();
        for (i, h) in handles.into_iter().enumerate() {
            let x = random_i8(64, 256, i as u64);
            assert_eq!(h.wait().out, x.widen().matmul(&ww), "request {i} diverged");
        }
    } else {
        for h in handles {
            h.wait();
        }
    }
    coord.shutdown()
}

fn main() {
    println!("=== Coordinator serving throughput (64x256 @ 256x256 requests) ===");
    let requests = 64;

    for devices in [1usize, 4, 8] {
        let r = bench(&format!("dip/devices{devices}/unbatched"), 1, 5, || {
            serve(Arch::Dip, devices, requests, 1, false).sim_cycles
        });
        report_throughput("requests", r.throughput(requests as f64), "/s");
    }

    for batch in [4usize, 16] {
        let r = bench(&format!("dip/devices4/batch{batch}"), 1, 5, || {
            serve(Arch::Dip, 4, requests, batch, false).sim_cycles
        });
        report_throughput("requests", r.throughput(requests as f64), "/s");
    }

    // Repeated-weight serving: the same 256x256 W across all requests
    // (one model layer under traffic). Affinity routing must turn the
    // repeats into skipped stationary reloads — and the outputs are
    // verified bit-exact against the i32 reference inside serve().
    println!("\n=== Repeated-weight affinity reuse (same W, {requests} requests) ===");
    let m = serve(Arch::Dip, 4, requests, 1, true);
    println!(
        "jobs {}  weight loads {}  skipped {} ({:.0}% reuse)  prepared-cache hits {}  steals {}  load cycles saved {}",
        m.jobs_executed,
        m.weight_loads,
        m.weight_loads_skipped,
        m.weight_reuse_rate() * 100.0,
        m.cache_hits,
        m.steals,
        m.weight_load_cycles_saved,
    );
    assert_eq!(m.weight_loads + m.weight_loads_skipped, m.jobs_executed);
    assert!(
        m.weight_loads_skipped > 0,
        "affinity scheduler must skip stationary reloads when serving one W repeatedly"
    );

    // DiP vs WS device pools: same requests, simulated cycle advantage.
    let dip_cycles = serve(Arch::Dip, 4, requests, 4, false).sim_cycles;
    let ws_cycles = serve(Arch::Ws, 4, requests, 4, false).sim_cycles;
    println!(
        "\nsimulated cycles: DiP {dip_cycles}, WS {ws_cycles} -> DiP {:.2}x fewer",
        ws_cycles as f64 / dip_cycles as f64
    );
    assert!(ws_cycles > dip_cycles, "DiP must win on simulated cycles");
}
