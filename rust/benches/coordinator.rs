//! Bench: L3 coordinator serving throughput — requests/s, batched vs
//! unbatched, DiP vs WS device pools. `cargo bench --bench coordinator`.

use dip_core::analytical::Arch;
use dip_core::bench_harness::timing::{bench, report_throughput};
use dip_core::coordinator::{Coordinator, CoordinatorConfig, DeviceConfig};
use dip_core::matrix::{random_i8, Mat};

fn serve(arch: Arch, devices: usize, requests: usize, batch: usize) -> u64 {
    let cfg = CoordinatorConfig {
        devices,
        device: DeviceConfig { arch, tile: 64, mac_stages: 2 },
        queue_depth: 256,
    };
    let coord = Coordinator::new(cfg);
    let w = random_i8(256, 256, 7);
    let mut handles = Vec::new();
    let mut i = 0;
    while i < requests {
        let chunk = batch.min(requests - i);
        let xs: Vec<Mat<i8>> = (0..chunk).map(|j| random_i8(64, 256, (i + j) as u64)).collect();
        handles.extend(coord.submit_batched(xs, w.clone()));
        i += chunk;
    }
    for h in handles {
        h.wait();
    }
    coord.shutdown().sim_cycles
}

fn main() {
    println!("=== Coordinator serving throughput (64x256 @ 256x256 requests) ===");
    let requests = 64;

    for devices in [1usize, 4, 8] {
        let r = bench(&format!("dip/devices{devices}/unbatched"), 1, 5, || {
            serve(Arch::Dip, devices, requests, 1)
        });
        report_throughput("requests", r.throughput(requests as f64), "/s");
    }

    for batch in [4usize, 16] {
        let r = bench(&format!("dip/devices4/batch{batch}"), 1, 5, || {
            serve(Arch::Dip, 4, requests, batch)
        });
        report_throughput("requests", r.throughput(requests as f64), "/s");
    }

    // DiP vs WS device pools: same requests, simulated cycle advantage.
    let dip_cycles = serve(Arch::Dip, 4, requests, 4);
    let ws_cycles = serve(Arch::Ws, 4, requests, 4);
    println!(
        "\nsimulated cycles: DiP {dip_cycles}, WS {ws_cycles} -> DiP {:.2}x fewer",
        ws_cycles as f64 / dip_cycles as f64
    );
    assert!(ws_cycles > dip_cycles, "DiP must win on simulated cycles");
}
