//! Bench: L3 coordinator serving throughput — requests/s, batched vs
//! unbatched, repeated-weight affinity reuse, heat-aware vs hash-mod
//! placement, multi-tenant fairness, DiP vs WS device pools.
//! `cargo bench --bench coordinator`.
//!
//! Emits `BENCH_coordinator.json` (throughput, cycles, reuse rates) so
//! future PRs can track scheduler-path regressions.
//!
//! Set `DIP_BENCH_SMOKE=1` to run reduced sizes (CI smoke: the same
//! scenarios and assertions, a fraction of the wall time).

use dip_core::analytical::Arch;
use dip_core::bench_harness::report::Json;
use dip_core::bench_harness::scenarios::{
    cold_share_with_growing_plug, serve_two_model_bursts, FloodScenario, TwoModelBurst,
};
use dip_core::bench_harness::timing::{bench, report_throughput, smoke_mode};
use dip_core::coordinator::{
    Coordinator, CoordinatorConfig, DeviceConfig, MetricsSnapshot, PlacementPolicy,
};
use dip_core::matrix::{random_i8, Mat};

fn config(arch: Arch, devices: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        devices,
        device: DeviceConfig { arch, tile: 64, mac_stages: 2, ..Default::default() },
        queue_depth: 256,
        ..Default::default()
    }
}

fn serve(arch: Arch, devices: usize, requests: usize, batch: usize, verify: bool) -> MetricsSnapshot {
    let coord = Coordinator::new(config(arch, devices));
    let w = random_i8(256, 256, 7);
    let mut handles = Vec::new();
    let mut i = 0;
    while i < requests {
        let chunk = batch.min(requests - i);
        let xs: Vec<Mat<i8>> = (0..chunk).map(|j| random_i8(64, 256, (i + j) as u64)).collect();
        handles.extend(coord.submit_batched(xs, w.clone()));
        i += chunk;
    }
    if verify {
        // Acceptance check: every served output bit-exact vs Mat::matmul.
        let ww = w.widen();
        for (i, h) in handles.into_iter().enumerate() {
            let x = random_i8(64, 256, i as u64);
            assert_eq!(h.wait().out, x.widen().matmul(&ww), "request {i} diverged");
        }
    } else {
        for h in handles {
            h.wait();
        }
    }
    // Every bench drain is also an audit point: the settled ledger must
    // pass the double-entry identities (check::audit) at full size.
    let (snap, audit) = coord.shutdown_audited();
    audit.assert_balanced();
    snap
}

fn placement_scenario(burst: usize) {
    println!(
        "\n=== Heat-aware placement vs `hash % devices` (2 models x 8 tiles, 4 devices, burst {burst}) ==="
    );
    // Deterministic A/B: sequential submit+wait, stealing off, outputs
    // verified bit-exact inside the shared scenario.
    let cfg = TwoModelBurst { tile: 16, seed_a: 2700, seed_b: 2750, burst };
    let hash = serve_two_model_bursts(&cfg, PlacementPolicy::HashMod);
    let heat = serve_two_model_bursts(&cfg, PlacementPolicy::HeatAware);
    println!(
        "hash-mod : jobs/device {:?}  max/min {:.2}  reuse {:.0}%",
        hash.device_jobs,
        hash.job_ratio(),
        hash.metrics.weight_reuse_rate() * 100.0
    );
    println!(
        "heat-aware: jobs/device {:?}  max/min {:.2}  reuse {:.0}%",
        heat.device_jobs,
        heat.job_ratio(),
        heat.metrics.weight_reuse_rate() * 100.0
    );
    // Acceptance: strictly higher reuse AND strictly lower per-device
    // job skew.
    assert!(
        heat.metrics.weight_reuse_rate() > hash.metrics.weight_reuse_rate(),
        "heat-aware reuse {:.3} must strictly beat hash {:.3}",
        heat.metrics.weight_reuse_rate(),
        hash.metrics.weight_reuse_rate()
    );
    assert!(
        heat.job_ratio() < hash.job_ratio(),
        "heat-aware job skew {:?} must be strictly tighter than {:?}",
        heat.device_jobs,
        hash.device_jobs
    );
    assert!(heat.job_spread() < hash.job_spread());
}

fn fairness_scenario(hot_requests: usize, cold_requests: usize, plug_rows: usize) {
    println!(
        "\n=== Two-tenant fairness (hot floods {hot_requests}, cold submits {cold_requests}, 1 device) ==="
    );
    let cfg = FloodScenario { tile: 16, hot_requests, cold_requests, plug_rows };
    let Some(out) = cold_share_with_growing_plug(cfg, 4) else {
        // Timing-inconclusive on this machine: the share measurement
        // needs a held backlog (exactness was still verified; the DRR
        // guarantee is covered by the queue-level unit tests).
        println!("fairness share inconclusive (backlog never held); skipping the floor check");
        return;
    };
    let share = out.cold_share.unwrap();
    println!(
        "at cold completion: hot served {}, cold served {} -> cold share {:.0}%",
        out.hot_served_at_cold_done,
        out.cold_served,
        share * 100.0
    );
    for t in &out.final_tenants {
        println!(
            "tenant {}: submitted {}  served {}  mean wait {:.2} ms  wait p50/p95/p99 {:.2}/{:.2}/{:.2} ms",
            t.tenant,
            t.requests_submitted,
            t.jobs_served,
            t.mean_wait().as_secs_f64() * 1e3,
            t.wait_hist.p50() as f64 / 1e6,
            t.wait_hist.p95() as f64 / 1e6,
            t.wait_hist.p99() as f64 / 1e6,
        );
        // The per-tenant wait histogram must conserve against the
        // counters it replaced: one sample per served job, sum exact.
        assert_eq!(
            t.wait_hist.count(),
            t.jobs_served,
            "tenant {} wait histogram lost samples",
            t.tenant
        );
        assert_eq!(t.wait_hist.sum(), t.wait_ns, "tenant {} wait histogram sum drifted", t.tenant);
        assert!(t.wait_hist.p95() >= t.wait_hist.p50(), "quantiles must be monotone");
    }
    assert!(
        share >= 0.25,
        "DRR must bound the cold tenant's share at >= 25% while the hot tenant floods (got {share:.2})"
    );
}

fn main() {
    let smoke = smoke_mode();
    let requests = if smoke { 8 } else { 64 };
    if smoke {
        println!("[smoke mode: reduced sizes]");
    }
    println!("=== Coordinator serving throughput (64x256 @ 256x256 requests) ===");

    let mut throughputs: Vec<(String, f64)> = Vec::new();
    for devices in [1usize, 4, 8] {
        let r = bench(&format!("dip/devices{devices}/unbatched"), 1, if smoke { 2 } else { 5 }, || {
            serve(Arch::Dip, devices, requests, 1, false).sim_cycles
        });
        report_throughput("requests", r.throughput(requests as f64), "/s");
        throughputs.push((format!("devices{devices}_unbatched"), r.throughput(requests as f64)));
    }

    for batch in [4usize, 16] {
        let r = bench(&format!("dip/devices4/batch{batch}"), 1, if smoke { 2 } else { 5 }, || {
            serve(Arch::Dip, 4, requests, batch, false).sim_cycles
        });
        report_throughput("requests", r.throughput(requests as f64), "/s");
        throughputs.push((format!("devices4_batch{batch}"), r.throughput(requests as f64)));
    }

    // Repeated-weight serving: the same 256x256 W across all requests
    // (one model layer under traffic). Affinity routing must turn the
    // repeats into skipped stationary reloads — and the outputs are
    // verified bit-exact against the i32 reference inside serve().
    println!("\n=== Repeated-weight affinity reuse (same W, {requests} requests) ===");
    let m = serve(Arch::Dip, 4, requests, 1, true);
    println!(
        "jobs {}  weight loads {}  skipped {} ({:.0}% reuse)  coalesced {} ({:.0}%)  prepared-cache hits {}  steals {}  load cycles saved {}",
        m.jobs_executed,
        m.weight_loads,
        m.weight_loads_skipped,
        m.weight_reuse_rate() * 100.0,
        m.jobs_coalesced,
        m.coalesce_rate() * 100.0,
        m.cache_hits,
        m.steals,
        m.weight_load_cycles_saved,
    );
    assert_eq!(m.weight_loads + m.weight_loads_skipped, m.jobs_executed);
    assert!(
        m.weight_loads_skipped > 0,
        "affinity scheduler must skip stationary reloads when serving one W repeatedly"
    );

    // Heat-aware placement A/B and multi-tenant fairness (the PR 2
    // scheduler-layer scenarios; deterministic placement asserts).
    placement_scenario(if smoke { 4 } else { 8 });
    fairness_scenario(
        if smoke { 120 } else { 240 },
        if smoke { 30 } else { 60 },
        if smoke { 1 << 14 } else { 1 << 16 },
    );

    // DiP vs WS device pools: same requests, simulated cycle advantage.
    let dip_cycles = serve(Arch::Dip, 4, requests, 4, false).sim_cycles;
    let ws_cycles = serve(Arch::Ws, 4, requests, 4, false).sim_cycles;
    println!(
        "\nsimulated cycles: DiP {dip_cycles}, WS {ws_cycles} -> DiP {:.2}x fewer",
        ws_cycles as f64 / dip_cycles as f64
    );
    assert!(ws_cycles > dip_cycles, "DiP must win on simulated cycles");

    // Machine-readable trajectory for future PRs.
    let json = Json::obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("requests", Json::num(requests as f64)),
        (
            "throughput_req_per_s",
            Json::obj(throughputs.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect()),
        ),
        ("repeated_weight_jobs", Json::num(m.jobs_executed as f64)),
        ("repeated_weight_loads_skipped", Json::num(m.weight_loads_skipped as f64)),
        ("repeated_weight_jobs_coalesced", Json::num(m.jobs_coalesced as f64)),
        ("repeated_weight_coalesce_rate", Json::num(m.coalesce_rate())),
        ("repeated_weight_reuse_rate", Json::num(m.weight_reuse_rate())),
        ("repeated_weight_cycles_saved", Json::num(m.weight_load_cycles_saved as f64)),
        ("steals", Json::num(m.steals as f64)),
        ("steals_warm", Json::num(m.steals_warm as f64)),
        ("dip_cycles", Json::num(dip_cycles as f64)),
        ("ws_cycles", Json::num(ws_cycles as f64)),
        ("ws_over_dip_cycles", Json::num(ws_cycles as f64 / dip_cycles as f64)),
    ]);
    std::fs::write("BENCH_coordinator.json", json.render()).expect("write BENCH_coordinator.json");
    println!("wrote BENCH_coordinator.json");
}
