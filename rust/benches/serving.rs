//! Bench: autoregressive serving — multi-session decode mix with
//! activation caching on vs off (KV-style row reuse + strip cache),
//! per-step latency/cycles/hit-rate reporting, and the acceptance
//! assertions (bit-exact outputs, strictly fewer streamed rows and
//! simulated cycles) — plus the continuous-batching A/B: the same
//! staggered join/leave session mix through the wave scheduler vs
//! per-session decode, asserting bit-exact outputs with **strictly
//! fewer weight-tile installs**, streamed rows, and simulated cycles.
//! `cargo bench --bench serving`.
//!
//! Emits `BENCH_serving.json` (machine-readable trajectory: cycles,
//! rows, reuse and hit rates, improvement ratios, wave metrics) so
//! future PRs can track serving-path regressions.
//!
//! Set `DIP_BENCH_SMOKE=1` for reduced sizes (CI smoke: same scenario,
//! same assertions — including the strict weight-load drop under
//! batching — at a fraction of the wall time).
//!
//! Every measured run's flight-recorder trace is validated and audited
//! against the settled ledger; the waved run's trace is exported as
//! `BENCH_serving_trace.json` (Chrome trace-event JSON — open in
//! Perfetto) and analytical-drift telemetry rides `BENCH_serving.json`.

use dip_core::analytical::Arch;
use dip_core::bench_harness::report::Json;
use dip_core::bench_harness::scenarios::{
    assert_cached_strictly_cheaper, assert_waved_strictly_cheaper, run_decode_mix, run_wave_mix,
    run_wave_mix_per_session, DecodeMix, DecodeOutcome, WaveMix, WaveOutcome, WaveSessionSpec,
};
use dip_core::bench_harness::timing::{bench, report_throughput, smoke_mode};
use dip_core::check::audit::{audit_critpath, audit_trace};
use dip_core::coordinator::MetricsSnapshot;
use dip_core::obs::{attribute, drift_report, what_if, Trace};
use dip_core::serving::{LayerDims, WavePolicy};

/// Gate on the recorder's contract: the trace is well-formed and its
/// event tallies conserve exactly against the settled ledger.
fn assert_trace_faithful(trace: &Trace, snap: &MetricsSnapshot, what: &str) {
    let violations = trace.validate();
    assert!(violations.is_empty(), "{what}: malformed trace:\n{}", violations.join("\n"));
    let report = audit_trace(&trace.counts(), snap);
    assert!(report.is_balanced(), "{what}: trace-ledger audit failed:\n{report}");
}

fn outcome_json(o: &DecodeOutcome) -> Json {
    let m = &o.metrics;
    Json::obj(vec![
        ("sim_cycles", Json::num(m.sim_cycles as f64)),
        ("rows_streamed", Json::num(m.rows_streamed as f64)),
        ("jobs_executed", Json::num(m.jobs_executed as f64)),
        ("jobs_coalesced", Json::num(m.jobs_coalesced as f64)),
        ("coalesce_rate", Json::num(m.coalesce_rate())),
        ("weight_loads", Json::num(m.weight_loads as f64)),
        ("weight_loads_skipped", Json::num(m.weight_loads_skipped as f64)),
        ("weight_reuse_rate", Json::num(m.weight_reuse_rate())),
        ("act_strip_hits", Json::num(m.act_strip_hits as f64)),
        ("act_strip_misses", Json::num(m.act_strip_misses as f64)),
        ("act_strip_hit_rate", Json::num(m.act_strip_hit_rate())),
        ("act_bytes_saved", Json::num(m.act_bytes_saved as f64)),
        ("act_rows_reused", Json::num(m.act_rows_reused as f64)),
        ("steals", Json::num(m.steals as f64)),
        ("steals_warm", Json::num(m.steals_warm as f64)),
    ])
}

fn main() {
    let smoke = smoke_mode();
    if smoke {
        println!("[smoke mode: reduced sizes]");
    }
    let cfg = DecodeMix {
        tile: if smoke { 8 } else { 16 },
        layers: 2,
        dims: if smoke {
            LayerDims { d_model: 16, d_k: 8, d_ffn: 24 }
        } else {
            LayerDims { d_model: 32, d_k: 16, d_ffn: 48 }
        },
        sessions: if smoke { 3 } else { 4 },
        prefill_rows: if smoke { 12 } else { 24 },
        shared_prefix_rows: if smoke { 8 } else { 16 },
        steps: if smoke { 4 } else { 8 },
        devices: 2,
        seed: 7100,
        strip_cache_capacity: 512,
    };
    let total_steps = (cfg.sessions * (cfg.steps + 1)) as f64;

    println!(
        "=== Serving decode mix ({} sessions x ({} prefill rows + {} steps), {} layers, d_model {}) ===",
        cfg.sessions, cfg.prefill_rows, cfg.steps, cfg.layers, cfg.dims.d_model
    );
    let r_cached = bench("serving/decode-mix/cached", 1, if smoke { 2 } else { 3 }, || {
        run_decode_mix(&cfg, true).metrics.sim_cycles
    });
    report_throughput("steps", r_cached.throughput(total_steps), "/s");
    let r_uncached = bench("serving/decode-mix/uncached", 1, if smoke { 2 } else { 3 }, || {
        run_decode_mix(&cfg, false).metrics.sim_cycles
    });
    report_throughput("steps", r_uncached.throughput(total_steps), "/s");

    // The measured A/B pair: acceptance criteria asserted (bit-exact
    // outputs; strictly fewer streamed rows and simulated cycles).
    let cached = run_decode_mix(&cfg, true);
    let uncached = run_decode_mix(&cfg, false);
    let ab = assert_cached_strictly_cheaper(&cached, &uncached);
    assert_trace_faithful(&cached.trace, &cached.metrics, "decode-mix/cached");
    assert_trace_faithful(&uncached.trace, &uncached.metrics, "decode-mix/uncached");
    let decode_drift = drift_report(&cached.trace, Arch::Dip, cfg.tile, 2);

    println!("\nper-step (cached run; session, rows streamed/total, cycles, strip hits, energy):");
    for r in &cached.per_step {
        println!(
            "  s{} rows {:>2}/{:<3} cycles {:>6}  strips {}/{}  reused rows {:>3}  {:>7.2} uJ  {:>8.1?}",
            r.session,
            r.rows_processed,
            r.total_rows,
            r.sim_cycles,
            r.strip_hits,
            r.strip_hits + r.strip_misses,
            r.rows_reused,
            r.energy_uj,
            r.wall,
        );
    }
    println!(
        "\ncached:   cycles {:>9}  rows {:>7}  strip hit rate {:>5.1}%  bytes saved {}",
        cached.metrics.sim_cycles,
        cached.metrics.rows_streamed,
        ab.strip_hit_rate * 100.0,
        ab.bytes_saved,
    );
    println!(
        "uncached: cycles {:>9}  rows {:>7}",
        uncached.metrics.sim_cycles, uncached.metrics.rows_streamed
    );
    println!(
        "-> activation caching: {:.2}x fewer simulated cycles, {:.2}x fewer streamed rows",
        ab.cycles_ratio, ab.rows_ratio
    );

    // === Continuous batching: wave scheduler vs per-session decode ===
    // A staggered mix — most sessions present from the start, two
    // joining mid-flight, lengths spread so sessions leave at
    // different waves — served both ways. The assertion set is the
    // acceptance criterion: bit-exact outputs, strictly fewer weight
    // loads, streamed rows, and simulated cycles.
    let wave_cfg = WaveMix {
        tile: cfg.tile,
        layers: cfg.layers,
        dims: cfg.dims,
        sessions: (0..if smoke { 4 } else { 6 })
            .map(|i| WaveSessionSpec {
                join_after: if i < 3 { 0 } else { 2 + (i - 3) * 2 },
                prompt_rows: cfg.prefill_rows - (i % 3),
                steps: cfg.steps + (i % 4),
            })
            .collect(),
        devices: cfg.devices,
        seed: cfg.seed + 1,
        strip_cache_capacity: cfg.strip_cache_capacity,
        policy: WavePolicy {
            max_wave_rows: 4 * cfg.prefill_rows,
            max_sessions: 8,
            ..Default::default()
        },
    };
    println!(
        "\n=== Continuous batching ({} sessions, staggered joins/leaves, budget {} rows) ===",
        wave_cfg.sessions.len(),
        wave_cfg.policy.max_wave_rows
    );
    let sessions_n = wave_cfg.sessions.len() as f64;
    let r_waved = bench("serving/wave-mix/batched", 1, if smoke { 2 } else { 3 }, || {
        run_wave_mix(&wave_cfg).metrics.sim_cycles
    });
    report_throughput("sessions", r_waved.throughput(sessions_n), "/s");
    let r_solo = bench("serving/wave-mix/per-session", 1, if smoke { 2 } else { 3 }, || {
        run_wave_mix_per_session(&wave_cfg).metrics.sim_cycles
    });
    report_throughput("sessions", r_solo.throughput(sessions_n), "/s");

    let waved = run_wave_mix(&wave_cfg);
    let solo = run_wave_mix_per_session(&wave_cfg);
    let wab = assert_waved_strictly_cheaper(&waved, &solo);
    assert_trace_faithful(&waved.trace, &waved.metrics, "wave-mix/batched");
    assert_trace_faithful(&solo.trace, &solo.metrics, "wave-mix/per-session");
    let wave_drift = drift_report(&waved.trace, Arch::Dip, wave_cfg.tile, 2);
    println!(
        "-> drift vs analytical closed forms: decode util {:.2} tfpu {:.2}, wave util {:.2} tfpu {:.2}",
        decode_drift.mean_util_drift,
        decode_drift.mean_tfpu_drift,
        wave_drift.mean_util_drift,
        wave_drift.mean_tfpu_drift,
    );

    println!("\nper-wave (sessions, stacked rows, joins, leaves, cycles):");
    for r in &waved.reports {
        println!(
            "  w{:<2} sess {:>2}  rows {:>3}  +{} -{}  cycles {:>7}  {:>7.2} uJ  {:>8.1?}",
            r.wave,
            r.sessions,
            r.stacked_rows,
            r.joined,
            r.completed.len(),
            r.sim_cycles,
            r.energy_uj,
            r.wall,
        );
    }
    println!(
        "\nwaved:       loads {:>5}  rows {:>7}  cycles {:>9}  ({} waves, {:.1} rows/wave, {:.1} loads/wave)",
        waved.metrics.weight_loads,
        waved.metrics.rows_streamed,
        waved.metrics.sim_cycles,
        waved.metrics.waves,
        wab.mean_wave_rows,
        wab.weight_loads_per_wave,
    );
    println!(
        "per-session: loads {:>5}  rows {:>7}  cycles {:>9}",
        solo.metrics.weight_loads, solo.metrics.rows_streamed, solo.metrics.sim_cycles
    );
    println!(
        "-> continuous batching: {:.2}x fewer weight loads, {:.2}x fewer streamed rows, {:.2}x fewer cycles",
        wab.weight_loads_ratio, wab.rows_ratio, wab.cycles_ratio
    );

    // === Critical-path profile of the batched run ===
    // The attribution must conserve (audited by name), and the what-if
    // "installs hidden" bound must reproduce the ledger's measured
    // install share within 1% — on a conserving trace the two are the
    // same quantity derived through independent paths (event walk vs
    // atomic counters), so this pins the profiler against the ledger.
    let profile_attr = attribute(&waved.trace);
    audit_critpath(&profile_attr, &waved.metrics).assert_balanced();
    let profile_bounds = what_if(&profile_attr);
    let ledger_install_share =
        waved.metrics.weight_load_cycles_charged as f64 / waved.metrics.sim_cycles as f64;
    assert!(
        (profile_bounds.install_share - ledger_install_share).abs() <= 0.01,
        "what-if install share {:.4} drifted from the ledger's {:.4}",
        profile_bounds.install_share,
        ledger_install_share
    );
    println!(
        "-> critical path: install share {:.1}% of busy cycles; installs-hidden bound {:.3}x, \
         perfect-balance bound {:.3}x",
        profile_bounds.install_share * 100.0,
        profile_bounds.bound("installs_hidden").map_or(1.0, |c| c.speedup_bound),
        profile_bounds.bound("perfect_balance").map_or(1.0, |c| c.speedup_bound),
    );

    let wave_json = |o: &WaveOutcome| {
        Json::obj(vec![
            ("sim_cycles", Json::num(o.metrics.sim_cycles as f64)),
            ("rows_streamed", Json::num(o.metrics.rows_streamed as f64)),
            ("jobs_executed", Json::num(o.metrics.jobs_executed as f64)),
            ("jobs_coalesced", Json::num(o.metrics.jobs_coalesced as f64)),
            ("coalesce_rate", Json::num(o.metrics.coalesce_rate())),
            ("weight_loads", Json::num(o.metrics.weight_loads as f64)),
            ("weight_loads_skipped", Json::num(o.metrics.weight_loads_skipped as f64)),
            ("waves", Json::num(o.metrics.waves as f64)),
            ("wave_stacked_rows", Json::num(o.metrics.wave_stacked_rows as f64)),
            ("weight_loads_per_wave", Json::num(o.metrics.weight_loads_per_wave())),
            ("mean_wave_rows", Json::num(o.metrics.mean_wave_rows())),
        ])
    };
    let json = Json::obj(vec![
        ("scenario", Json::str("decode_mix")),
        ("smoke", Json::Bool(smoke)),
        ("sessions", Json::num(cfg.sessions as f64)),
        ("prefill_rows", Json::num(cfg.prefill_rows as f64)),
        ("steps", Json::num(cfg.steps as f64)),
        ("layers", Json::num(cfg.layers as f64)),
        ("tile", Json::num(cfg.tile as f64)),
        ("steps_per_s_cached", Json::num(r_cached.throughput(total_steps))),
        ("steps_per_s_uncached", Json::num(r_uncached.throughput(total_steps))),
        ("cycles_ratio", Json::num(ab.cycles_ratio)),
        ("rows_ratio", Json::num(ab.rows_ratio)),
        ("cached", outcome_json(&cached)),
        ("uncached", outcome_json(&uncached)),
        ("drift", decode_drift.to_json()),
        ("wait_ns_p50", Json::num(cached.trace.merged_wait_hist().p50() as f64)),
        ("wait_ns_p95", Json::num(cached.trace.merged_wait_hist().p95() as f64)),
        ("wait_ns_p99", Json::num(cached.trace.merged_wait_hist().p99() as f64)),
        (
            "wave_mix",
            Json::obj(vec![
                ("sessions", Json::num(sessions_n)),
                ("max_wave_rows", Json::num(wave_cfg.policy.max_wave_rows as f64)),
                ("weight_loads_ratio", Json::num(wab.weight_loads_ratio)),
                ("cycles_ratio", Json::num(wab.cycles_ratio)),
                ("rows_ratio", Json::num(wab.rows_ratio)),
                ("sessions_per_s_batched", Json::num(r_waved.throughput(sessions_n))),
                ("sessions_per_s_per_session", Json::num(r_solo.throughput(sessions_n))),
                ("batched", wave_json(&waved)),
                ("per_session", wave_json(&solo)),
                ("drift", wave_drift.to_json()),
            ]),
        ),
        (
            "profile",
            Json::obj(vec![
                ("attribution", profile_attr.to_json()),
                ("what_if", profile_bounds.to_json()),
            ]),
        ),
    ]);
    std::fs::write("BENCH_serving.json", json.render()).expect("write BENCH_serving.json");
    std::fs::write("BENCH_serving_trace.json", waved.trace.chrome_json().render())
        .expect("write BENCH_serving_trace.json");
    println!("\nwrote BENCH_serving.json + BENCH_serving_trace.json");
}
