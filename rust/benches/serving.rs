//! Bench: autoregressive serving — multi-session decode mix with
//! activation caching on vs off (KV-style row reuse + strip cache),
//! per-step latency/cycles/hit-rate reporting, and the acceptance
//! assertions (bit-exact outputs, strictly fewer streamed rows and
//! simulated cycles). `cargo bench --bench serving`.
//!
//! Emits `BENCH_serving.json` (machine-readable trajectory: cycles,
//! rows, reuse and hit rates, improvement ratios) so future PRs can
//! track serving-path regressions.
//!
//! Set `DIP_BENCH_SMOKE=1` for reduced sizes (CI smoke: same scenario,
//! same assertions, fraction of the wall time).

use dip_core::bench_harness::report::Json;
use dip_core::bench_harness::scenarios::{
    assert_cached_strictly_cheaper, run_decode_mix, DecodeMix, DecodeOutcome,
};
use dip_core::bench_harness::timing::{bench, report_throughput};
use dip_core::serving::LayerDims;

fn smoke() -> bool {
    std::env::var("DIP_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

fn outcome_json(o: &DecodeOutcome) -> Json {
    let m = &o.metrics;
    Json::obj(vec![
        ("sim_cycles", Json::num(m.sim_cycles as f64)),
        ("rows_streamed", Json::num(m.rows_streamed as f64)),
        ("jobs_executed", Json::num(m.jobs_executed as f64)),
        ("weight_loads", Json::num(m.weight_loads as f64)),
        ("weight_loads_skipped", Json::num(m.weight_loads_skipped as f64)),
        ("weight_reuse_rate", Json::num(m.weight_reuse_rate())),
        ("act_strip_hits", Json::num(m.act_strip_hits as f64)),
        ("act_strip_misses", Json::num(m.act_strip_misses as f64)),
        ("act_strip_hit_rate", Json::num(m.act_strip_hit_rate())),
        ("act_bytes_saved", Json::num(m.act_bytes_saved as f64)),
        ("act_rows_reused", Json::num(m.act_rows_reused as f64)),
        ("steals", Json::num(m.steals as f64)),
        ("steals_warm", Json::num(m.steals_warm as f64)),
    ])
}

fn main() {
    let smoke = smoke();
    if smoke {
        println!("[smoke mode: reduced sizes]");
    }
    let cfg = DecodeMix {
        tile: if smoke { 8 } else { 16 },
        layers: 2,
        dims: if smoke {
            LayerDims { d_model: 16, d_k: 8, d_ffn: 24 }
        } else {
            LayerDims { d_model: 32, d_k: 16, d_ffn: 48 }
        },
        sessions: if smoke { 3 } else { 4 },
        prefill_rows: if smoke { 12 } else { 24 },
        shared_prefix_rows: if smoke { 8 } else { 16 },
        steps: if smoke { 4 } else { 8 },
        devices: 2,
        seed: 7100,
        strip_cache_capacity: 512,
    };
    let total_steps = (cfg.sessions * (cfg.steps + 1)) as f64;

    println!(
        "=== Serving decode mix ({} sessions x ({} prefill rows + {} steps), {} layers, d_model {}) ===",
        cfg.sessions, cfg.prefill_rows, cfg.steps, cfg.layers, cfg.dims.d_model
    );
    let r_cached = bench("serving/decode-mix/cached", 1, if smoke { 2 } else { 3 }, || {
        run_decode_mix(&cfg, true).metrics.sim_cycles
    });
    report_throughput("steps", r_cached.throughput(total_steps), "/s");
    let r_uncached = bench("serving/decode-mix/uncached", 1, if smoke { 2 } else { 3 }, || {
        run_decode_mix(&cfg, false).metrics.sim_cycles
    });
    report_throughput("steps", r_uncached.throughput(total_steps), "/s");

    // The measured A/B pair: acceptance criteria asserted (bit-exact
    // outputs; strictly fewer streamed rows and simulated cycles).
    let cached = run_decode_mix(&cfg, true);
    let uncached = run_decode_mix(&cfg, false);
    let ab = assert_cached_strictly_cheaper(&cached, &uncached);

    println!("\nper-step (cached run; session, rows streamed/total, cycles, strip hits, energy):");
    for r in &cached.per_step {
        println!(
            "  s{} rows {:>2}/{:<3} cycles {:>6}  strips {}/{}  reused rows {:>3}  {:>7.2} uJ  {:>8.1?}",
            r.session,
            r.rows_processed,
            r.total_rows,
            r.sim_cycles,
            r.strip_hits,
            r.strip_hits + r.strip_misses,
            r.rows_reused,
            r.energy_uj,
            r.wall,
        );
    }
    println!(
        "\ncached:   cycles {:>9}  rows {:>7}  strip hit rate {:>5.1}%  bytes saved {}",
        cached.metrics.sim_cycles,
        cached.metrics.rows_streamed,
        ab.strip_hit_rate * 100.0,
        ab.bytes_saved,
    );
    println!(
        "uncached: cycles {:>9}  rows {:>7}",
        uncached.metrics.sim_cycles, uncached.metrics.rows_streamed
    );
    println!(
        "-> activation caching: {:.2}x fewer simulated cycles, {:.2}x fewer streamed rows",
        ab.cycles_ratio, ab.rows_ratio
    );

    let json = Json::obj(vec![
        ("scenario", Json::str("decode_mix")),
        ("smoke", Json::Bool(smoke)),
        ("sessions", Json::num(cfg.sessions as f64)),
        ("prefill_rows", Json::num(cfg.prefill_rows as f64)),
        ("steps", Json::num(cfg.steps as f64)),
        ("layers", Json::num(cfg.layers as f64)),
        ("tile", Json::num(cfg.tile as f64)),
        ("steps_per_s_cached", Json::num(r_cached.throughput(total_steps))),
        ("steps_per_s_uncached", Json::num(r_uncached.throughput(total_steps))),
        ("cycles_ratio", Json::num(ab.cycles_ratio)),
        ("rows_ratio", Json::num(ab.rows_ratio)),
        ("cached", outcome_json(&cached)),
        ("uncached", outcome_json(&uncached)),
    ]);
    std::fs::write("BENCH_serving.json", json.render()).expect("write BENCH_serving.json");
    println!("\nwrote BENCH_serving.json");
}
