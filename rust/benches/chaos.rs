//! Bench: chaos — graceful degradation of the device fleet.
//! `cargo bench --bench chaos`.
//!
//! Two measurements, emitted as `BENCH_chaos.json`:
//!
//! * **Degraded capacity**: the same continuous-batching session mix
//!   served fault-free on 4, then 3, then 2 devices — the planned
//!   headroom curve a deployment consults before draining devices.
//!   Outputs must be bit-identical across fleet sizes (placement moves
//!   work, never changes math).
//! * **Mid-bench kill**: the 4-device mix under a schedule whose only
//!   event is one device dying permanently a few jobs in. The
//!   acceptance criterion is asserted: every session completes with
//!   outputs bit-exact against the healthy fleet, the trace↔ledger
//!   audit balances, and wall-clock throughput degrades by less than
//!   2x (the fleet loses a quarter of its capacity, not half).
//!
//! Set `DIP_BENCH_SMOKE=1` for reduced sizes (CI smoke: same scenario,
//! same assertions, with wall-ratio slack for shared runners).

use dip_core::bench_harness::report::Json;
use dip_core::bench_harness::scenarios::{
    run_wave_mix, run_wave_mix_with_faults, WaveMix, WaveSessionSpec,
};
use dip_core::bench_harness::timing::{bench, report_throughput, smoke_mode};
use dip_core::check::audit::audit_trace;
use dip_core::fault::FaultPlan;
use dip_core::serving::{LayerDims, WavePolicy};

/// Device killed by the mid-bench death schedule.
const KILL_VICTIM: usize = 1;
/// The victim dies on reaching its 4th job (slot 3) — early enough to
/// leave most of the mix to the survivors, late enough to strand an
/// installed tile and an in-flight backlog worth reclaiming.
const KILL_SLOT: u64 = 3;

fn mix(devices: usize, smoke: bool) -> WaveMix {
    let steps = if smoke { 3 } else { 8 };
    let prompt = if smoke { 10 } else { 20 };
    WaveMix {
        tile: 8,
        layers: 2,
        dims: LayerDims { d_model: 16, d_k: 8, d_ffn: 24 },
        sessions: (0..if smoke { 4 } else { 6 })
            .map(|i| WaveSessionSpec {
                join_after: if i < 3 { 0 } else { i - 2 },
                prompt_rows: prompt - (i % 3),
                steps: steps + (i % 3),
            })
            .collect(),
        devices,
        seed: 8200,
        strip_cache_capacity: 512,
        policy: WavePolicy::default(),
    }
}

/// A schedule whose only event is [`KILL_VICTIM`] dying permanently at
/// [`KILL_SLOT`]. No job faults, so the measured slowdown is pure
/// capacity loss plus reclamation/re-homing overhead.
fn kill_plan(devices: usize) -> FaultPlan {
    let mut death_at = vec![None; devices];
    death_at[KILL_VICTIM] = Some(KILL_SLOT);
    FaultPlan { faults: vec![Vec::new(); devices], death_at, retry_immunity: true }
}

fn main() {
    let smoke = smoke_mode();
    if smoke {
        println!("[smoke mode: reduced sizes]");
    }
    let iters = if smoke { 2 } else { 3 };

    // === Degraded capacity: fault-free runs on a shrinking fleet ===
    println!("=== Degraded capacity (fault-free, shrinking fleet) ===");
    let mut capacity_json = Vec::new();
    let mut sweep = Vec::new();
    for devices in [4usize, 3, 2] {
        let cfg = mix(devices, smoke);
        let sessions_n = cfg.sessions.len() as f64;
        let r = bench(&format!("chaos/capacity/devices{devices}"), 1, iters, || {
            run_wave_mix(&cfg).metrics.sim_cycles
        });
        report_throughput("sessions", r.throughput(sessions_n), "/s");
        let o = run_wave_mix(&cfg);
        assert_eq!(o.acts.len(), cfg.sessions.len(), "{devices} devices: sessions lost");
        capacity_json.push(Json::obj(vec![
            ("devices", Json::num(devices as f64)),
            ("sim_cycles", Json::num(o.metrics.sim_cycles as f64)),
            ("jobs_executed", Json::num(o.metrics.jobs_executed as f64)),
            ("requests_completed", Json::num(o.metrics.requests_completed as f64)),
            ("sessions_per_s", Json::num(r.throughput(sessions_n))),
        ]));
        sweep.push((devices, r, o));
    }
    // Fleet size moves work between devices but never the math: every
    // fleet size must produce bit-identical session outputs.
    for (devices, _, o) in &sweep[1..] {
        assert_eq!(o.acts, sweep[0].2.acts, "{devices} devices: token rows diverged");
        assert_eq!(o.layers, sweep[0].2.layers, "{devices} devices: K/V/Y state diverged");
    }

    // === Mid-bench kill: 1 of 4 devices dies, survivors finish ===
    println!("\n=== Mid-bench kill: device {KILL_VICTIM} of 4 dies at job {KILL_SLOT} ===");
    let cfg = mix(4, smoke);
    let sessions_n = cfg.sessions.len() as f64;
    let r_clean = sweep[0].1;
    let r_kill = bench("chaos/kill-1-of-4", 1, iters, || {
        run_wave_mix_with_faults(&cfg, kill_plan(4)).metrics.sim_cycles
    });
    report_throughput("sessions", r_kill.throughput(sessions_n), "/s");

    let clean = &sweep[0].2;
    let chaotic = run_wave_mix_with_faults(&cfg, kill_plan(4));

    // All sessions complete, bit-exact against the healthy fleet, and
    // the flight recorder's tallies conserve against the ledger.
    assert_eq!(chaotic.acts, clean.acts, "kill run: token rows diverged");
    assert_eq!(chaotic.layers, clean.layers, "kill run: K/V/Y state diverged");
    assert_eq!(chaotic.metrics.requests_completed, clean.metrics.requests_completed);
    assert_eq!(chaotic.metrics.jobs_executed, clean.metrics.jobs_executed);
    assert_eq!(chaotic.metrics.device_deaths, 1, "the victim never died");
    let violations = chaotic.trace.validate();
    assert!(violations.is_empty(), "kill run: malformed trace:\n{}", violations.join("\n"));
    let report = audit_trace(&chaotic.trace.counts(), &chaotic.metrics);
    assert!(report.is_balanced(), "kill run: trace-ledger audit failed:\n{report}");

    // The acceptance bound: losing 1 of 4 devices costs < 2x wall
    // throughput. Smoke runs are milliseconds on shared CI cores, so
    // the smoke bound carries noise slack.
    let wall_factor = r_kill.median.as_secs_f64() / r_clean.median.as_secs_f64();
    let cycles_factor = chaotic.metrics.sim_cycles as f64 / clean.metrics.sim_cycles as f64;
    let limit = if smoke { 2.5 } else { 2.0 };
    assert!(
        wall_factor < limit,
        "killing 1 of 4 devices degraded wall throughput {wall_factor:.2}x (limit {limit}x)"
    );
    println!(
        "-> kill 1/4: wall {:.2}x, simulated cycles {:.2}x, {} job(s) reclaimed, \
         all {} sessions bit-exact",
        wall_factor,
        cycles_factor,
        chaotic.metrics.jobs_reclaimed,
        cfg.sessions.len()
    );

    let json = Json::obj(vec![
        ("scenario", Json::str("chaos_degraded_capacity")),
        ("smoke", Json::Bool(smoke)),
        ("sessions", Json::num(sessions_n)),
        ("capacity", Json::Arr(capacity_json)),
        (
            "kill",
            Json::obj(vec![
                ("victim", Json::num(KILL_VICTIM as f64)),
                ("death_slot", Json::num(KILL_SLOT as f64)),
                ("sessions_per_s_clean", Json::num(r_clean.throughput(sessions_n))),
                ("sessions_per_s_killed", Json::num(r_kill.throughput(sessions_n))),
                ("wall_factor", Json::num(wall_factor)),
                ("cycles_factor", Json::num(cycles_factor)),
                ("sim_cycles_clean", Json::num(clean.metrics.sim_cycles as f64)),
                ("requests_completed", Json::num(chaotic.metrics.requests_completed as f64)),
                ("jobs_executed", Json::num(chaotic.metrics.jobs_executed as f64)),
                ("device_deaths", Json::num(chaotic.metrics.device_deaths as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_chaos.json", json.render()).expect("write BENCH_chaos.json");
    println!("\nwrote BENCH_chaos.json");
}
