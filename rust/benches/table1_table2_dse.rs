//! Bench: regenerate the hardware design-space exploration (Table I and
//! Table II) from the calibrated 22nm component model, plus the
//! weight-load-policy and clock-gating ablations called out in
//! DESIGN.md. `cargo bench --bench table1_table2_dse`.

use dip_core::analytical::Arch;
use dip_core::bench_harness::{table1, table2, timing::bench};
use dip_core::power::energy::energy_pj_gated;
use dip_core::tiling::schedule::{workload_cost, TilingConfig, WeightLoadPolicy};
use dip_core::workloads::dims::MatMulDims;

fn main() {
    println!("=== Table I / Table II regeneration (22nm DSE) ===");
    print!("{}", table1::render(&table1::run()));
    println!();
    print!("{}", table2::render(&table2::run()));

    bench("table1/model_eval", 2, 50, table1::run);
    bench("table2/model_eval", 2, 50, table2::run);

    // --- Ablation 1: weight-load policy (overlapped vs blocking) ---
    println!("\n=== Ablation: weight-load policy (64x64, DiP) ===");
    for dims in [MatMulDims::new(64, 64, 64), MatMulDims::new(512, 512, 512)] {
        let over = workload_cost(dims, &TilingConfig::dip64());
        let block = workload_cost(
            dims,
            &TilingConfig { weight_load: WeightLoadPolicy::Blocking, ..TilingConfig::dip64() },
        );
        println!(
            "{dims}: overlapped {} cycles, blocking {} cycles (+{:.1}%)",
            over.cycles,
            block.cycles,
            (block.cycles as f64 / over.cycles as f64 - 1.0) * 100.0
        );
    }

    // --- Ablation 2: paper power-x-latency vs event-based energy ---
    println!("\n=== Ablation: energy accounting (64-64-64 small workload) ===");
    for arch in [Arch::Ws, Arch::Dip] {
        let cfg = if arch == Arch::Ws { TilingConfig::ws64() } else { TilingConfig::dip64() };
        let c = workload_cost(MatMulDims::new(64, 64, 64), &cfg);
        let gated = energy_pj_gated(64, &c.stats).total_uj();
        println!(
            "{}: paper-accounting {:.3} uJ, event-based {:.3} uJ, event+gated {:.3} uJ",
            arch.name(),
            c.energy_uj,
            c.energy_event_uj,
            gated
        );
    }
    println!("(gating idle PEs shrinks the WS fill/drain penalty — the paper's");
    println!(" power-x-latency accounting is the upper bound of DiP's benefit)");
}
