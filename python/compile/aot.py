"""AOT compiler: lower the L2/L1 computations to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The HLO text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under ``artifacts/``):

  dip_tile_matmul.hlo.txt    single 64x64 DiP tile pass (dataflow body)
  matmul_ref_64.hlo.txt      plain 64x64 matmul oracle
  matmul_dip_256.hlo.txt     256x256 DiP matmul (mxu body), unpermutated W in
  matmul_ref_256.hlo.txt     256x256 plain matmul oracle
  mha_dip.hlo.txt            MHA block on DiP (BlockConfig below)
  mha_ref.hlo.txt            MHA block reference
  ffn_dip.hlo.txt            FFN block on DiP
  ffn_ref.hlo.txt            FFN block reference
  layer_dip.hlo.txt          full transformer layer on DiP
  layer_ref.hlo.txt          full transformer layer reference
  manifest.json              name -> {file, inputs: [[dims...]...]}

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import dip_matmul as dk

# The serving config for the AOT artifacts: a small-but-real transformer
# layer (ALBERT-scale slice). Kept modest so the interpret-mode Pallas
# grids stay tractable for CPU-PJRT compile + execute; the cycle-accurate
# evaluation in Rust covers the full paper model sweep independently.
CFG = M.BlockConfig(seq_len=128, d_model=256, num_heads=4, d_ff=1024)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def build_entries():
    """(name, fn, example_specs) for every artifact."""
    cfg = CFG
    cfg.validate()
    l, d, f = cfg.seq_len, cfg.d_model, cfg.d_ff
    mha_in = [_spec(l, d)] + [_spec(d, d)] * 4
    ffn_in = [_spec(l, d), _spec(d, f), _spec(f), _spec(f, d), _spec(d)]
    layer_in = mha_in + ffn_in[1:]

    return [
        ("dip_tile_matmul", M.dip_tile_matmul, [_spec(64, 64), _spec(64, 64)]),
        ("matmul_ref_64", M.matmul_reference, [_spec(64, 64), _spec(64, 64)]),
        (
            "matmul_dip_256",
            lambda x, w: dk.dip_linear(x, w, mode="mxu"),
            [_spec(256, 256), _spec(256, 256)],
        ),
        ("matmul_ref_256", M.matmul_reference, [_spec(256, 256), _spec(256, 256)]),
        ("mha_dip", lambda *a: M.mha_dip(cfg, *a), mha_in),
        ("mha_ref", lambda *a: M.mha_reference(cfg, *a), mha_in),
        ("ffn_dip", lambda *a: M.ffn_dip(cfg, *a), ffn_in),
        ("ffn_ref", lambda *a: M.ffn_reference(cfg, *a), ffn_in),
        ("layer_dip", lambda *a: M.transformer_layer_dip(cfg, *a), layer_in),
        ("layer_ref", lambda *a: M.transformer_layer_reference(cfg, *a), layer_in),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact names to rebuild"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {
        "config": {
            "seq_len": CFG.seq_len,
            "d_model": CFG.d_model,
            "num_heads": CFG.num_heads,
            "d_ff": CFG.d_ff,
            "tile": CFG.tile,
        },
        "artifacts": {},
    }
    for name, fn, specs in build_entries():
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [list(s.shape) for s in specs],
            "dtype": "f32",
            "returns_tuple1": True,
        }
        print(f"  wrote {fname}: {len(text)} chars, inputs {[s.shape for s in specs]}")

    man_path = os.path.join(args.out_dir, "manifest.json")
    if only is not None and os.path.exists(man_path):
        with open(man_path) as f:
            old = json.load(f)
        old["artifacts"].update(manifest["artifacts"])
        manifest = old
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
