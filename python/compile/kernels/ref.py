"""Pure-jnp reference oracle for the DiP permutated-weight dataflow.

Everything in this file is the *specification*: the Pallas kernel
(`dip_matmul.py`), the JAX model (`model.py`), and the Rust cycle-accurate
simulators are all validated against these functions.

Paper reference (Fig. 3 pseudocode):

    for i in range(cols):
        for j in range(rows):
            permutated_matrix[j][i] = matrix[(j + i) % rows][i]

i.e. column ``i`` is rotated *up* by ``i`` rows. The DiP identity is then

    out[m, c] = sum_k x[m, (c + k) % K] * Wp[k, c]  ==  (X @ W)[m, c]
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def permute_weights(w: jnp.ndarray) -> jnp.ndarray:
    """DiP weight permutation: rotate column ``i`` up by ``i`` rows.

    ``Wp[j, i] = W[(j + i) % rows, i]`` — the exact Fig. 3 pseudocode,
    vectorized. Works for rectangular matrices (rotation is modulo the
    row count).
    """
    rows, cols = w.shape
    j = jnp.arange(rows)[:, None]
    i = jnp.arange(cols)[None, :]
    return w[(j + i) % rows, i]


def unpermute_weights(wp: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`permute_weights`: ``W[j, i] = Wp[(j - i) % rows, i]``."""
    rows, cols = wp.shape
    j = jnp.arange(rows)[:, None]
    i = jnp.arange(cols)[None, :]
    return wp[(j - i) % rows, i]


def permute_weights_np(w: np.ndarray) -> np.ndarray:
    """NumPy twin of :func:`permute_weights` (used by tests as a second,
    independently-written oracle — literal transcription of Fig. 3)."""
    rows, cols = w.shape
    out = np.empty_like(w)
    for i in range(cols):
        for j in range(rows):
            out[j][i] = w[(j + i) % rows][i]
    return out


def dip_matmul_ref(x: jnp.ndarray, wp: jnp.ndarray) -> jnp.ndarray:
    """DiP dataflow transcription: rotate-and-MAC over permutated weights.

    ``x`` is (M, K), ``wp`` is the *permutated* (K, N) weight matrix with
    square rotation modulo K. Computes ``x @ unpermute(wp)`` by the same
    recurrence the hardware performs — one rotated input row per PE row:

        acc += roll(x, -k, axis=1) * wp[k, :]

    Only valid when K == N (square tile, like the NxN array). For the
    general case go through :func:`unpermute_weights` + ``@``.
    """
    m, k = x.shape
    k2, n = wp.shape
    assert k == k2 == n, "dataflow transcription requires a square tile"
    acc = jnp.zeros((m, n), dtype=jnp.promote_types(x.dtype, jnp.float32))
    for s in range(k):
        acc = acc + jnp.roll(x, -s, axis=1).astype(acc.dtype) * wp[s, :].astype(acc.dtype)
    return acc


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain reference matmul with f32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def softmax_ref(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    x = x - jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def gelu_ref(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (matches model.py)."""
    return 0.5 * x * (1.0 + jnp.tanh(jnp.sqrt(2.0 / jnp.pi) * (x + 0.044715 * x**3)))


def mha_ref(
    x: jnp.ndarray,
    wq: jnp.ndarray,
    wk: jnp.ndarray,
    wv: jnp.ndarray,
    wo: jnp.ndarray,
    num_heads: int,
) -> jnp.ndarray:
    """Reference multi-head attention per paper eqs (8.1)-(8.5).

    ``x``: (l, d_model); ``wq/wk/wv``: (d_model, d_model); ``wo``:
    (d_model, d_model). Head size d_k = d_model / num_heads.
    """
    l, d_model = x.shape
    d_k = d_model // num_heads
    q = matmul_ref(x, wq)
    k = matmul_ref(x, wk)
    v = matmul_ref(x, wv)

    def head(i):
        qi = q[:, i * d_k : (i + 1) * d_k]
        ki = k[:, i * d_k : (i + 1) * d_k]
        vi = v[:, i * d_k : (i + 1) * d_k]
        s = softmax_ref(matmul_ref(qi, ki.T) / jnp.sqrt(jnp.float32(d_k)))
        return matmul_ref(s, vi)

    attn = jnp.concatenate([head(i) for i in range(num_heads)], axis=1)
    return matmul_ref(attn, wo)


def ffn_ref(
    y: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
) -> jnp.ndarray:
    """Reference FFN per paper eqs (9.1)-(9.2), GELU non-linearity."""
    z = gelu_ref(matmul_ref(y, w1) + b1)
    return matmul_ref(z, w2) + b2
