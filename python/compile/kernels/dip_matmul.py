"""L1 Pallas kernel: DiP permutated-weight tiled matmul.

The paper's DiP array stores the weight matrix *permutated* (each column
``i`` rotated up by ``i`` rows) so the input can flow diagonally with
zero skew-FIFO overhead. On TPU the analogue is: keep the weight tile
permutated in VMEM and reconstruct ``X @ W`` inside the kernel. The
BlockSpec grid expresses the HBM<->VMEM schedule that the paper's tiling
methodology (SIV.C) expresses with stationary M2 tiles.

Two kernel bodies are provided:

* ``mode="dataflow"`` — the faithful transcription of the hardware: a
  K-step rotate-multiply-accumulate recurrence, one step per PE row.
  Each step is a full-row vector op (the "full PE-row utilization" the
  paper claims), no gathers, static rotations only.
* ``mode="mxu"`` — the production path: un-permute the weight tile once
  per (k) block with a static gather, then issue a single
  ``jnp.dot(..., preferred_element_type=f32)`` that maps onto the MXU
  systolic array. This is what the AOT model artifacts use.

Both are validated against ``ref.py`` by pytest/hypothesis. Kernels run
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls); real-TPU
perf is estimated from VMEM footprint + MXU utilization in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import permute_weights, unpermute_weights

# Default tile: matches the paper's 64x64 evaluation arrays and the TPU
# MXU native 128-lane layout (64 is a clean half-tile for interpret runs).
DEFAULT_TILE = 64


def _unpermute_tile(wp: jnp.ndarray) -> jnp.ndarray:
    """Static un-permutation of one (T, T) weight tile inside the kernel.

    ``W[j, c] = Wp[(j - c) % T, c]``. The index matrix is a compile-time
    constant, so this lowers to a single gather with a static index
    operand (cheap on TPU; in the paper's hardware it is free because the
    permutation is pre-applied in memory).
    """
    t, n = wp.shape
    j = jax.lax.broadcasted_iota(jnp.int32, (t, n), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (t, n), 1)
    return jnp.take_along_axis(wp, (j - c) % t, axis=0)


def _dip_kernel_mxu(x_ref, wp_ref, o_ref, *, nsteps_k: int):
    """Un-permute the VMEM-resident weight tile, then one MXU matmul."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _unpermute_tile(wp_ref[...].astype(jnp.float32))
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )


def _dip_kernel_dataflow(x_ref, wp_ref, o_ref, *, nsteps_k: int):
    """Faithful DiP recurrence: T rotate-MAC steps, one per PE row.

    acc[m, c] += x[m, (c + s) % T] * Wp[s, c]  for s = 0..T-1

    ``jnp.roll`` with a static shift is a lax.concatenate of two static
    slices — no gather, mirroring the hardware's wire-only diagonal
    interconnect.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    wp = wp_ref[...].astype(jnp.float32)
    t = wp.shape[0]
    acc = jnp.zeros_like(o_ref)
    # Static unroll: each step is the software image of "input row enters
    # PE row s, rotated left s times by the diagonal interconnect".
    for s in range(t):
        acc += jnp.roll(x, -s, axis=1) * wp[s, :][None, :]
    o_ref[...] += acc


def dip_matmul(
    x: jnp.ndarray,
    wp: jnp.ndarray,
    *,
    tile_m: int = DEFAULT_TILE,
    tile_t: int = DEFAULT_TILE,
    mode: str = "mxu",
    interpret: bool = True,
) -> jnp.ndarray:
    """Compute ``x @ unpermute(wp)`` with the DiP permutated dataflow.

    Args:
      x: (M, K) input activations.
      wp: (K, N) weight matrix already permutated *per (tile_t, tile_t)
        tile* (see :func:`permute_weights_tiled`).
      tile_m: rows of X processed per grid step.
      tile_t: square tile edge — the "array size" N of the paper.
      mode: "mxu" (un-permute + dot) or "dataflow" (rotate-MAC).
      interpret: must stay True on CPU PJRT.

    Shapes must be multiples of the tile sizes; the tiling layer in Rust
    zero-pads ragged edges before dispatch, and `model.py` asserts it.
    """
    m, kdim = x.shape
    k2, n = wp.shape
    assert kdim == k2, f"contraction mismatch {kdim} vs {k2}"
    assert m % tile_m == 0, f"M={m} not a multiple of tile_m={tile_m}"
    assert kdim % tile_t == 0, f"K={kdim} not a multiple of tile={tile_t}"
    assert n % tile_t == 0, f"N={n} not a multiple of tile={tile_t}"
    grid = (m // tile_m, n // tile_t, kdim // tile_t)

    body = _dip_kernel_mxu if mode == "mxu" else _dip_kernel_dataflow
    return pl.pallas_call(
        functools.partial(body, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_t), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_t, tile_t), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_t), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, wp)


def permute_weights_tiled(
    w: jnp.ndarray, *, tile_t: int = DEFAULT_TILE
) -> jnp.ndarray:
    """Permute a (K, N) weight matrix independently per (tile_t, tile_t)
    tile — exactly what the paper's SIV.C tiling does: "every tile of M2
    is loaded once and remains stationary", each tile permutated for its
    own 64x64 array pass.
    """
    kdim, n = w.shape
    assert kdim % tile_t == 0 and n % tile_t == 0
    w = w.reshape(kdim // tile_t, tile_t, n // tile_t, tile_t)
    # vmap the single-tile permutation over both tile grids.
    perm = jax.vmap(jax.vmap(permute_weights))(w.transpose(0, 2, 1, 3))
    return perm.transpose(0, 2, 1, 3).reshape(kdim, n)


def unpermute_weights_tiled(
    wp: jnp.ndarray, *, tile_t: int = DEFAULT_TILE
) -> jnp.ndarray:
    """Inverse of :func:`permute_weights_tiled`."""
    kdim, n = wp.shape
    assert kdim % tile_t == 0 and n % tile_t == 0
    wp = wp.reshape(kdim // tile_t, tile_t, n // tile_t, tile_t)
    unperm = jax.vmap(jax.vmap(unpermute_weights))(wp.transpose(0, 2, 1, 3))
    return unperm.transpose(0, 2, 1, 3).reshape(kdim, n)


def dip_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    tile_m: int = DEFAULT_TILE,
    tile_t: int = DEFAULT_TILE,
    mode: str = "mxu",
    interpret: bool = True,
) -> jnp.ndarray:
    """Convenience wrapper taking an *unpermutated* weight: permutes at
    trace time ("at run-time in memory at almost zero cost" — paper
    SIII.B) then dispatches the DiP kernel."""
    wp = permute_weights_tiled(w, tile_t=tile_t)
    return dip_matmul(
        x, wp, tile_m=tile_m, tile_t=tile_t, mode=mode, interpret=interpret
    )
