"""L2: transformer workload blocks (MHA + FFN) built on the DiP kernel.

These are the paper's benchmark workloads (SIV.B, Table III): every matrix
multiplication — input projections, attention scores, attention output,
output projection, and both FFN projections — runs through the DiP
permutated-weight Pallas kernel. A twin set of `*_reference` functions
computes the same blocks with plain jnp matmuls; `aot.py` emits both so
the Rust runtime can assert allclose between the DiP artifact and the
reference artifact end-to-end.

Build-time only: this module is lowered once to HLO text and never
imported on the request path.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .kernels import dip_matmul as dk
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """Hyper-parameters of one transformer layer (paper Table III naming).

    All dims must be multiples of ``tile`` — the paper notes "the majority
    of MHA and FFN workload dimensions are divisible by 64"; the Rust
    tiling layer zero-pads the rest before ever reaching this code.
    """

    seq_len: int = 128  # l
    d_model: int = 256  # model hidden size
    num_heads: int = 4  # h
    d_ff: int = 1024  # FFN size
    tile: int = 64  # DiP array edge N
    mode: str = "mxu"  # dip kernel body: "mxu" | "dataflow"

    @property
    def d_k(self) -> int:
        return self.d_model // self.num_heads

    def validate(self) -> None:
        for name in ("seq_len", "d_model", "d_ff"):
            v = getattr(self, name)
            if v % self.tile != 0:
                raise ValueError(f"{name}={v} not a multiple of tile={self.tile}")
        if self.d_model % self.num_heads != 0:
            raise ValueError("d_model must divide into heads")
        if self.d_k % self.tile != 0:
            raise ValueError(f"d_k={self.d_k} not a multiple of tile={self.tile}")


def _mm(cfg: BlockConfig, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """One DiP matmul: permute-at-load + diagonal-dataflow kernel."""
    return dk.dip_linear(x, w, tile_m=cfg.tile, tile_t=cfg.tile, mode=cfg.mode)


def mha_dip(cfg: BlockConfig, x, wq, wk, wv, wo):
    """Multi-head attention, eqs (8.1)-(8.5), all matmuls on DiP.

    x: (l, d_model); wq/wk/wv/wo: (d_model, d_model).
    """
    d_k = cfg.d_k
    q = _mm(cfg, x, wq)
    k = _mm(cfg, x, wk)
    v = _mm(cfg, x, wv)
    heads = []
    for i in range(cfg.num_heads):
        sl = slice(i * d_k, (i + 1) * d_k)
        # Attention scores l x d_k x l (Table III row 2): the "weight" is
        # K^T, permutated at run time in memory (paper SIII.B).
        s = _mm(cfg, q[:, sl], k[:, sl].T) / jnp.sqrt(jnp.float32(d_k))
        s = ref.softmax_ref(s)
        # Attn_i = S_i V_i, l x l x d_k (Table III row 3).
        heads.append(_mm(cfg, s, v[:, sl]))
    attn = jnp.concatenate(heads, axis=1)  # eq (8.4)
    return _mm(cfg, attn, wo)  # eq (8.5)


def mha_reference(cfg: BlockConfig, x, wq, wk, wv, wo):
    """Same block with plain matmuls (the numerics oracle)."""
    return ref.mha_ref(x, wq, wk, wv, wo, cfg.num_heads)


def ffn_dip(cfg: BlockConfig, y, w1, b1, w2, b2):
    """Feed-forward network, eqs (9.1)-(9.2), both projections on DiP."""
    z = ref.gelu_ref(_mm(cfg, y, w1) + b1)
    return _mm(cfg, z, w2) + b2


def ffn_reference(cfg: BlockConfig, y, w1, b1, w2, b2):
    return ref.ffn_ref(y, w1, b1, w2, b2)


def transformer_layer_dip(cfg: BlockConfig, x, wq, wk, wv, wo, w1, b1, w2, b2):
    """One full transformer layer on DiP: x + MHA, then + FFN.

    (LayerNorm omitted: the paper's accelerator evaluates the matmul
    stages; norms run on the host in its system model.)
    """
    h = x + mha_dip(cfg, x, wq, wk, wv, wo)
    return h + ffn_dip(cfg, h, w1, b1, w2, b2)


def transformer_layer_reference(cfg: BlockConfig, x, wq, wk, wv, wo, w1, b1, w2, b2):
    h = x + mha_reference(cfg, x, wq, wk, wv, wo)
    return h + ffn_reference(cfg, h, w1, b1, w2, b2)


def dip_tile_matmul(x, wp):
    """The single-tile DiP primitive (faithful dataflow body) the Rust
    coordinator dispatches per 64x64 tile: x (64,64) @ unpermute(wp)."""
    return dk.dip_matmul(x, wp, tile_m=64, tile_t=64, mode="dataflow")


def matmul_reference(x, w):
    return ref.matmul_ref(x, w)
