"""AOT pipeline tests: every artifact lowers to parseable HLO text, the
manifest is consistent, and the lowered computations execute correctly
through XLA (the same path the Rust runtime takes)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_all_entries_lower(self):
        for name, fn, specs in aot.build_entries():
            text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_hlo_text_has_no_64bit_id_issue_markers(self):
        """The interchange is plain text — no serialized proto artifacts."""
        name, fn, specs = aot.build_entries()[0]
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert "\x00" not in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
class TestManifest:
    def setup_method(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            self.manifest = json.load(f)

    def test_every_artifact_file_exists(self):
        for name, meta in self.manifest["artifacts"].items():
            path = os.path.join(ART, meta["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), name

    def test_expected_artifact_set(self):
        names = set(self.manifest["artifacts"])
        assert {
            "dip_tile_matmul",
            "matmul_ref_64",
            "mha_dip",
            "mha_ref",
            "ffn_dip",
            "ffn_ref",
            "layer_dip",
            "layer_ref",
        } <= names

    def test_dip_ref_pairs_have_matching_inputs(self):
        arts = self.manifest["artifacts"]
        for a, b in [("mha_dip", "mha_ref"), ("ffn_dip", "ffn_ref"), ("layer_dip", "layer_ref")]:
            assert arts[a]["inputs"] == arts[b]["inputs"]

    def test_config_recorded(self):
        cfg = self.manifest["config"]
        assert cfg["tile"] == 64
        assert cfg["d_model"] % cfg["num_heads"] == 0


class TestExecutedNumerics:
    """Execute the *lowered* computations (XLA compile + run, same as the
    Rust side) and compare dip vs ref pairs."""

    def _run_pair(self, dip_name, ref_name, seed=0):
        entries = {n: (fn, specs) for n, fn, specs in aot.build_entries()}
        fn_d, specs = entries[dip_name]
        fn_r, _ = entries[ref_name]
        keys = jax.random.split(jax.random.PRNGKey(seed), len(specs))
        args = [
            jax.random.normal(k, s.shape, s.dtype) / np.sqrt(max(s.shape[-1], 1))
            for k, s in zip(keys, specs)
        ]
        got = jax.jit(fn_d)(*args)
        want = jax.jit(fn_r)(*args)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
        )

    def test_mha_pair(self):
        self._run_pair("mha_dip", "mha_ref")

    def test_ffn_pair(self):
        self._run_pair("ffn_dip", "ffn_ref")

    def test_layer_pair(self):
        self._run_pair("layer_dip", "layer_ref", seed=1)

    def test_tile_matmul_pair(self):
        from compile.kernels import ref as R

        x = jax.random.normal(jax.random.PRNGKey(2), (64, 64))
        w = jax.random.normal(jax.random.PRNGKey(3), (64, 64))
        got = jax.jit(M.dip_tile_matmul)(x, R.permute_weights(w))
        want = jax.jit(M.matmul_reference)(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
