"""L2 correctness: DiP-backed transformer blocks vs plain-jnp references,
plus shape/config validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.BlockConfig(seq_len=64, d_model=128, num_heads=2, d_ff=256)


def params(cfg, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 9)
    l, d, f = cfg.seq_len, cfg.d_model, cfg.d_ff
    sc = 1.0 / np.sqrt(d)
    x = jax.random.normal(keys[0], (l, d)) * sc
    wq, wk, wv, wo = (jax.random.normal(keys[i], (d, d)) * sc for i in range(1, 5))
    w1 = jax.random.normal(keys[5], (d, f)) * sc
    b1 = jax.random.normal(keys[6], (f,)) * 0.01
    w2 = jax.random.normal(keys[7], (f, d)) * sc
    b2 = jax.random.normal(keys[8], (d,)) * 0.01
    return x, wq, wk, wv, wo, w1, b1, w2, b2


class TestMHA:
    def test_dip_matches_reference(self):
        x, wq, wk, wv, wo, *_ = params(CFG)
        got = M.mha_dip(CFG, x, wq, wk, wv, wo)
        want = M.mha_reference(CFG, x, wq, wk, wv, wo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_output_shape(self):
        x, wq, wk, wv, wo, *_ = params(CFG)
        out = M.mha_dip(CFG, x, wq, wk, wv, wo)
        assert out.shape == (CFG.seq_len, CFG.d_model)

    def test_softmax_rows_sum_to_one_effect(self):
        """With V = identity-ish columns, MHA output stays bounded by the
        value range (softmax is a convex combination)."""
        x, wq, wk, wv, wo, *_ = params(CFG, seed=3)
        out = np.asarray(M.mha_dip(CFG, x, wq, wk, wv, wo))
        assert np.isfinite(out).all()

    def test_head_count_affects_output(self):
        cfg2 = M.BlockConfig(seq_len=64, d_model=128, num_heads=1, d_ff=256)
        x, wq, wk, wv, wo, *_ = params(CFG)
        a = np.asarray(M.mha_reference(CFG, x, wq, wk, wv, wo))
        b = np.asarray(M.mha_reference(cfg2, x, wq, wk, wv, wo))
        assert not np.allclose(a, b)


class TestFFN:
    def test_dip_matches_reference(self):
        x, *_ , w1, b1, w2, b2 = params(CFG)
        got = M.ffn_dip(CFG, x, w1, b1, w2, b2)
        want = M.ffn_reference(CFG, x, w1, b1, w2, b2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_zero_bias_zero_input(self):
        l, d, f = CFG.seq_len, CFG.d_model, CFG.d_ff
        out = M.ffn_dip(
            CFG,
            jnp.zeros((l, d)),
            jnp.ones((d, f)),
            jnp.zeros((f,)),
            jnp.ones((f, d)),
            jnp.zeros((d,)),
        )
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


class TestLayer:
    def test_dip_matches_reference(self):
        p = params(CFG, seed=7)
        got = M.transformer_layer_dip(CFG, *p)
        want = M.transformer_layer_reference(CFG, *p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_residual_path(self):
        """Zero weights -> layer is the identity (residuals only)."""
        l, d, f = CFG.seq_len, CFG.d_model, CFG.d_ff
        x = jax.random.normal(jax.random.PRNGKey(9), (l, d))
        z_dd = jnp.zeros((d, d))
        out = M.transformer_layer_dip(
            CFG, x, z_dd, z_dd, z_dd, z_dd,
            jnp.zeros((d, f)), jnp.zeros((f,)), jnp.zeros((f, d)), jnp.zeros((d,)),
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-5, atol=1e-5)


class TestConfig:
    def test_default_valid(self):
        M.BlockConfig().validate()

    @pytest.mark.parametrize(
        "kw",
        [
            {"seq_len": 100},
            {"d_model": 200},
            {"d_ff": 1000},
            {"num_heads": 3},
            {"d_model": 128, "num_heads": 4},  # d_k = 32 < tile
        ],
    )
    def test_invalid_configs_rejected(self, kw):
        with pytest.raises(ValueError):
            M.BlockConfig(**kw).validate()

    def test_d_k(self):
        assert M.BlockConfig(d_model=512, num_heads=8).d_k == 64
