"""L1 correctness: DiP Pallas kernel vs pure-jnp oracle.

This is the CORE correctness signal for the compile path: if these pass,
every HLO artifact the Rust runtime executes computes exactly X @ W
through the permutated dataflow.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dip_matmul as dk
from compile.kernels import ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Permutation identities (Fig. 3 pseudocode)
# ---------------------------------------------------------------------------


class TestPermutation:
    @pytest.mark.parametrize("n", [1, 2, 3, 4, 8, 16, 64, 128])
    def test_roundtrip_square(self, n):
        w = rand(n, (n, n))
        np.testing.assert_array_equal(
            np.asarray(ref.unpermute_weights(ref.permute_weights(w))), np.asarray(w)
        )

    @pytest.mark.parametrize("rows,cols", [(4, 8), (8, 4), (3, 5), (64, 128)])
    def test_roundtrip_rect(self, rows, cols):
        w = rand(rows * 131 + cols, (rows, cols))
        np.testing.assert_array_equal(
            np.asarray(ref.unpermute_weights(ref.permute_weights(w))), np.asarray(w)
        )

    @pytest.mark.parametrize("n", [2, 3, 5, 8, 64])
    def test_matches_paper_pseudocode(self, n):
        """Vectorized permutation == literal Fig. 3 double loop."""
        w = np.random.default_rng(n).standard_normal((n, n)).astype(np.float32)
        np.testing.assert_array_equal(
            np.asarray(ref.permute_weights(jnp.asarray(w))),
            ref.permute_weights_np(w),
        )

    def test_fig4_example_3x3(self):
        """The exact 3x3 permutation from paper Fig. 4(b).

        The original weight matrix is W = [[a,d,g],[b,e,h],[c,f,i]]
        (letters column-major); Fig. 4 shows its permutation — the matrix
        actually loaded into the array — as [[a,e,i],[b,f,g],[c,d,h]]:
        column 1 rotated up by 1 -> (e,f,d), column 2 by 2 -> (i,g,h).
        """
        a, b, c, d, e, f, g, h, i = range(1, 10)
        w = jnp.array([[a, d, g], [b, e, h], [c, f, i]], jnp.float32)
        expect = jnp.array([[a, e, i], [b, f, g], [c, d, h]], jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(ref.permute_weights(w)), np.asarray(expect)
        )

    def test_permutation_is_bijection(self):
        n = 16
        idx = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)
        p = np.asarray(ref.permute_weights(idx)).ravel()
        assert sorted(p.tolist()) == list(range(n * n))

    def test_tiled_roundtrip(self):
        w = rand(7, (192, 128))
        wp = dk.permute_weights_tiled(w, tile_t=64)
        np.testing.assert_array_equal(
            np.asarray(dk.unpermute_weights_tiled(wp, tile_t=64)), np.asarray(w)
        )

    def test_tiled_permutes_each_tile_independently(self):
        w = rand(9, (128, 128))
        wp = dk.permute_weights_tiled(w, tile_t=64)
        for bi in range(2):
            for bj in range(2):
                tile = w[bi * 64 : (bi + 1) * 64, bj * 64 : (bj + 1) * 64]
                np.testing.assert_array_equal(
                    np.asarray(wp[bi * 64 : (bi + 1) * 64, bj * 64 : (bj + 1) * 64]),
                    np.asarray(ref.permute_weights(tile)),
                )


# ---------------------------------------------------------------------------
# DiP dataflow identity (the heart of the paper)
# ---------------------------------------------------------------------------


class TestDataflowIdentity:
    @pytest.mark.parametrize("n", [2, 3, 4, 8, 16, 64])
    def test_rotate_mac_equals_matmul(self, n):
        x = rand(n, (5, n))
        w = rand(n + 1, (n, n))
        out = ref.dip_matmul_ref(x, ref.permute_weights(w))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(x @ w), rtol=1e-4, atol=1e-4
        )

    def test_fig4_numeric_walkthrough(self):
        """Paper Fig. 4: X = [[1,2,3],[4,5,6],[7,8,9]],
        W = [[a,d,g],[b,e,h],[c,f,i]] whose permutation (the loaded
        matrix) is Wp = [[a,e,i],[b,f,g],[c,d,h]].

        The paper's cycle-3/4/5 output rows are
          row0 = (1a+2b+3c, 2e+3f+1d, 3i+1g+2h)
          row1 = (4a+5b+6c, 5e+6f+4d, 6i+4g+5h)
          row2 = (7a+8b+9c, 8e+9f+7d, 9i+7g+8h)
        """
        a, b, c, d, e, f, g, h, i = [float(v) for v in range(1, 10)]
        w = jnp.array([[a, d, g], [b, e, h], [c, f, i]], jnp.float32)
        wp = ref.permute_weights(w)
        np.testing.assert_array_equal(
            np.asarray(wp),
            np.asarray(jnp.array([[a, e, i], [b, f, g], [c, d, h]], jnp.float32)),
        )
        x = jnp.array([[1, 2, 3], [4, 5, 6], [7, 8, 9]], jnp.float32)
        out = np.asarray(ref.dip_matmul_ref(x, wp))
        expect = np.array(
            [
                [1 * a + 2 * b + 3 * c, 2 * e + 3 * f + 1 * d, 3 * i + 1 * g + 2 * h],
                [4 * a + 5 * b + 6 * c, 5 * e + 6 * f + 4 * d, 6 * i + 4 * g + 5 * h],
                [7 * a + 8 * b + 9 * c, 8 * e + 9 * f + 7 * d, 9 * i + 7 * g + 8 * h],
            ]
        )
        np.testing.assert_allclose(out, expect, rtol=1e-6)
        np.testing.assert_allclose(out, np.asarray(x @ w), rtol=1e-6)


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------


class TestPallasKernel:
    @pytest.mark.parametrize("mode", ["mxu", "dataflow"])
    def test_single_tile(self, mode):
        x = rand(10, (64, 64))
        w = rand(11, (64, 64))
        out = dk.dip_matmul(x, ref.permute_weights(w), mode=mode)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.matmul_ref(x, w)), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("mode", ["mxu", "dataflow"])
    @pytest.mark.parametrize(
        "m,k,n", [(64, 64, 128), (128, 128, 64), (64, 192, 64), (128, 128, 128)]
    )
    def test_multi_tile(self, mode, m, k, n):
        x = rand(m + k, (m, k))
        w = rand(n + k, (k, n))
        out = dk.dip_linear(x, w, mode=mode)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.matmul_ref(x, w)), rtol=1e-4, atol=1e-4
        )

    def test_modes_agree(self):
        x = rand(20, (128, 128))
        w = rand(21, (128, 128))
        a = dk.dip_linear(x, w, mode="mxu")
        b = dk.dip_linear(x, w, mode="dataflow")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)

    def test_tile_m_independent(self):
        x = rand(30, (128, 64))
        w = rand(31, (64, 64))
        a = dk.dip_linear(x, w, tile_m=64)
        b = dk.dip_linear(x, w, tile_m=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = rand(40, (64, 64), dtype)
        w = rand(41, (64, 64), dtype)
        out = dk.dip_linear(x, w)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(ref.matmul_ref(x, w)),
            rtol=tol,
            atol=tol,
        )

    def test_rejects_ragged_shapes(self):
        x = rand(50, (65, 64))
        w = rand(51, (64, 64))
        with pytest.raises(AssertionError):
            dk.dip_linear(x, w)

    def test_zero_input(self):
        out = dk.dip_matmul(jnp.zeros((64, 64)), jnp.zeros((64, 64)))
        assert float(jnp.abs(out).max()) == 0.0

    def test_identity_weight(self):
        """X @ I == X through the permuted dataflow."""
        x = rand(60, (64, 64))
        out = dk.dip_linear(x, jnp.eye(64, dtype=jnp.float32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, dtypes, values
# ---------------------------------------------------------------------------

TILES = st.sampled_from([64])
MULT = st.integers(min_value=1, max_value=3)


class TestHypothesisSweeps:
    @settings(max_examples=15, deadline=None)
    @given(mi=MULT, ki=MULT, ni=MULT, seed=st.integers(0, 2**31 - 1))
    def test_kernel_matches_ref_any_shape(self, mi, ki, ni, seed):
        m, k, n = 64 * mi, 64 * ki, 64 * ni
        key = jax.random.PRNGKey(seed)
        kx, kw = jax.random.split(key)
        x = jax.random.uniform(kx, (m, k), jnp.float32, -2.0, 2.0)
        w = jax.random.uniform(kw, (k, n), jnp.float32, -2.0, 2.0)
        out = dk.dip_linear(x, w, mode="mxu")
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.matmul_ref(x, w)), rtol=1e-3, atol=1e-3
        )

    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([2, 3, 4, 5, 8, 16]), seed=st.integers(0, 2**31 - 1))
    def test_permutation_roundtrip_any_n(self, n, seed):
        w = jax.random.normal(jax.random.PRNGKey(seed), (n, n))
        np.testing.assert_array_equal(
            np.asarray(ref.unpermute_weights(ref.permute_weights(w))), np.asarray(w)
        )

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.sampled_from([2, 3, 4, 8, 16, 32]),
        m=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_dataflow_identity_any_n(self, n, m, seed):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.uniform(kx, (m, n), jnp.float32, -1.0, 1.0)
        w = jax.random.uniform(kw, (n, n), jnp.float32, -1.0, 1.0)
        out = ref.dip_matmul_ref(x, ref.permute_weights(w))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(x @ w), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
    )
    def test_kernel_value_ranges(self, seed, scale):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (64, 64)) * scale
        w = jax.random.normal(kw, (64, 64)) * scale
        out = dk.dip_linear(x, w)
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(ref.matmul_ref(x, w)),
            rtol=1e-3,
            atol=1e-3 * scale * scale,
        )
